//! `swcnn-lint` — repo-specific static analysis for the swcnn engine.
//!
//! The engine's core guarantees are invariants ordinary rustc/clippy cannot
//! see: fused Winograd loops must not allocate, every `unsafe` region must
//! justify itself, library code must surface errors as typed values rather
//! than panic, and nothing outside the coordinator may read wall-clock time
//! (the deterministic fault-injection plan depends on it).  This crate checks
//! those invariants at the source level so they survive refactors.
//!
//! Four rules, each keyed by a stable id used in `allow.list`:
//!
//! | id              | invariant                                              |
//! |-----------------|--------------------------------------------------------|
//! | `unsafe-safety` | every `unsafe` fn/block/impl carries a `// SAFETY:`    |
//! |                 | comment (or a `# Safety` doc section for `unsafe fn`)  |
//! | `hot-no-alloc`  | fns annotated `// lint: hot` contain no allocation     |
//! |                 | idioms (`Vec::new`, `vec![`, `.to_vec(`, `.collect(`,  |
//! |                 | `.clone(`, `Box::new`, `format!`)                      |
//! | `no-unwrap`     | no `.unwrap()` / `.expect(` in library code outside    |
//! |                 | `#[cfg(test)]` (binaries `main.rs`/`bin/` exempt)      |
//! | `no-wall-clock` | no `Instant::now` / `SystemTime` outside               |
//! |                 | `coordinator/` and the bench modules                   |
//!
//! The scan is line-based but comment- and string-aware: each file is first
//! "scrubbed" into parallel code/comment views so needles inside string
//! literals or prose never fire, and `#[cfg(test)]` regions are tracked by
//! brace depth so test code is exempt where a rule says so.  Findings that
//! are genuinely fine (e.g. `try_into().unwrap()` on a fixed-size slice)
//! are suppressed by `allow.list` entries of the form
//! `rule-id path-suffix line-substring` — no line numbers, so entries
//! tolerate drift and unused entries are reported.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The four invariants, keyed by stable ids used in findings and allowlists.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// `unsafe` fn/block/impl without an adjacent `// SAFETY:` justification.
    UnsafeSafety,
    /// Allocation idiom inside a fn annotated `// lint: hot`.
    HotNoAlloc,
    /// `.unwrap()` / `.expect(` in non-test library code.
    NoUnwrap,
    /// Wall-clock read outside `coordinator/` and the benches.
    NoWallClock,
}

impl Rule {
    /// Stable id used in output lines and `allow.list`.
    pub fn id(self) -> &'static str {
        match self {
            Rule::UnsafeSafety => "unsafe-safety",
            Rule::HotNoAlloc => "hot-no-alloc",
            Rule::NoUnwrap => "no-unwrap",
            Rule::NoWallClock => "no-wall-clock",
        }
    }

    /// Inverse of [`Rule::id`], for allowlist validation.
    pub fn from_id(id: &str) -> Option<Rule> {
        match id {
            "unsafe-safety" => Some(Rule::UnsafeSafety),
            "hot-no-alloc" => Some(Rule::HotNoAlloc),
            "no-unwrap" => Some(Rule::NoUnwrap),
            "no-wall-clock" => Some(Rule::NoWallClock),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One rule violation at a specific line of a scanned file.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: Rule,
    /// Scan-root-relative path, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
    /// The raw source line, used for allowlist substring matching.
    pub raw_line: String,
}

/// One suppression: `rule path-suffix line-substring` from `allow.list`.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: String,
    pub path_suffix: String,
    pub needle: String,
}

/// Result of scanning a directory tree.
#[derive(Debug)]
pub struct TreeScan {
    /// Number of `.rs` files scanned.
    pub files: usize,
    pub findings: Vec<Finding>,
}

// ---------------------------------------------------------------------------
// Source scrubbing: split a file into parallel code / comment views.
// ---------------------------------------------------------------------------

/// Per-line views of one source file, aligned line-for-line with the input.
#[derive(Debug)]
struct Scrubbed {
    /// Source lines with comments, string/char literal contents, and raw
    /// strings blanked to spaces.  Needle searches run against these.
    code: Vec<String>,
    /// The complement: comment text only (everything else blanked).
    comment: Vec<String>,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn prev_is_ident(cs: &[char], i: usize) -> bool {
    i > 0 && is_ident(cs[i - 1])
}

/// Strips comments and literal contents while preserving line structure.
///
/// Handles line/nested-block comments, plain and raw (`r#"…"#`) string
/// literals, byte strings, char literals, and the char-vs-lifetime
/// ambiguity (`'a'` vs `&'a`).  Escaped newlines inside string literals
/// keep their `\n` so line numbers stay aligned.
fn scrub(src: &str) -> Scrubbed {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u8),
        Char,
    }

    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut code = String::with_capacity(n);
    let mut com = String::with_capacity(n);
    let mut st = St::Code;
    let mut i = 0;

    // Push helpers: every input char maps to exactly one output char in both
    // views, so line/column structure is preserved.
    macro_rules! push_code {
        ($c:expr) => {{
            code.push($c);
            com.push(if $c == '\n' { '\n' } else { ' ' });
        }};
    }
    macro_rules! push_com {
        ($c:expr) => {{
            com.push($c);
            code.push(if $c == '\n' { '\n' } else { ' ' });
        }};
    }
    macro_rules! push_none {
        ($c:expr) => {{
            let keep = if $c == '\n' { '\n' } else { ' ' };
            code.push(keep);
            com.push(keep);
        }};
    }

    while i < n {
        let c = cs[i];
        match st {
            St::Code => {
                if c == '/' && i + 1 < n && cs[i + 1] == '/' {
                    st = St::LineComment;
                    push_none!(c);
                    push_none!(cs[i + 1]);
                    i += 2;
                } else if c == '/' && i + 1 < n && cs[i + 1] == '*' {
                    st = St::BlockComment(1);
                    push_none!(c);
                    push_none!(cs[i + 1]);
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    push_none!(c);
                    i += 1;
                } else if c == 'r' && !prev_is_ident(&cs, i) {
                    // Raw string r"…" / r#"…"# (any hash depth).
                    let mut j = i + 1;
                    let mut hashes = 0u8;
                    while j < n && cs[j] == '#' {
                        hashes = hashes.saturating_add(1);
                        j += 1;
                    }
                    if j < n && cs[j] == '"' {
                        st = St::RawStr(hashes);
                        for k in i..=j {
                            push_none!(cs[k]);
                        }
                        i = j + 1;
                    } else {
                        push_code!(c);
                        i += 1;
                    }
                } else if c == 'b' && !prev_is_ident(&cs, i) && i + 1 < n && cs[i + 1] == '"' {
                    st = St::Str;
                    push_none!(c);
                    push_none!(cs[i + 1]);
                    i += 2;
                } else if c == 'b'
                    && !prev_is_ident(&cs, i)
                    && i + 1 < n
                    && cs[i + 1] == 'r'
                {
                    let mut j = i + 2;
                    let mut hashes = 0u8;
                    while j < n && cs[j] == '#' {
                        hashes = hashes.saturating_add(1);
                        j += 1;
                    }
                    if j < n && cs[j] == '"' {
                        st = St::RawStr(hashes);
                        for k in i..=j {
                            push_none!(cs[k]);
                        }
                        i = j + 1;
                    } else {
                        push_code!(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    if i + 1 < n && cs[i + 1] == '\\' {
                        // Escaped char literal: '\n', '\'', '\u{…}'.
                        st = St::Char;
                        push_none!(c);
                        push_none!(cs[i + 1]);
                        i += 2;
                    } else if i + 2 < n && cs[i + 2] == '\'' && cs[i + 1] != '\'' {
                        // Plain char literal 'x'.
                        push_none!(c);
                        push_none!(cs[i + 1]);
                        push_none!(cs[i + 2]);
                        i += 3;
                    } else {
                        // Lifetime: keep the tick as code.
                        push_code!(c);
                        i += 1;
                    }
                } else {
                    push_code!(c);
                    i += 1;
                }
            }
            St::LineComment => {
                if c == '\n' {
                    st = St::Code;
                    push_none!(c);
                } else {
                    push_com!(c);
                }
                i += 1;
            }
            St::BlockComment(depth) => {
                if c == '*' && i + 1 < n && cs[i + 1] == '/' {
                    st = if depth <= 1 {
                        St::Code
                    } else {
                        St::BlockComment(depth - 1)
                    };
                    push_none!(c);
                    push_none!(cs[i + 1]);
                    i += 2;
                } else if c == '/' && i + 1 < n && cs[i + 1] == '*' {
                    st = St::BlockComment(depth + 1);
                    push_none!(c);
                    push_none!(cs[i + 1]);
                    i += 2;
                } else {
                    push_com!(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' && i + 1 < n {
                    push_none!(c);
                    push_none!(cs[i + 1]);
                    i += 2;
                } else {
                    if c == '"' {
                        st = St::Code;
                    }
                    push_none!(c);
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut seen = 0u8;
                    while j < n && cs[j] == '#' && seen < hashes {
                        seen += 1;
                        j += 1;
                    }
                    if seen == hashes {
                        for k in i..j {
                            push_none!(cs[k]);
                        }
                        st = St::Code;
                        i = j;
                        continue;
                    }
                }
                push_none!(c);
                i += 1;
            }
            St::Char => {
                if c == '\'' {
                    st = St::Code;
                }
                push_none!(c);
                i += 1;
            }
        }
    }

    let code_lines = code.split('\n').map(str::to_string).collect();
    let com_lines = com.split('\n').map(str::to_string).collect();
    Scrubbed {
        code: code_lines,
        comment: com_lines,
    }
}

// ---------------------------------------------------------------------------
// Needle search with identifier-boundary awareness.
// ---------------------------------------------------------------------------

/// Finds `needle` in `hay` starting at byte `from`, requiring identifier
/// boundaries only on needle edges that are themselves identifier chars
/// (so `.unwrap()` matches after any receiver, but `SystemTime` does not
/// match inside `MySystemTimeish`).
fn find_needle(hay: &str, needle: &str, from: usize) -> Option<usize> {
    let first_ident = needle.chars().next().is_some_and(is_ident);
    let last_ident = needle.chars().next_back().is_some_and(is_ident);
    let mut start = from;
    while start <= hay.len() {
        let pos = hay[start..].find(needle)?;
        let abs = start + pos;
        let before_ok =
            !first_ident || !hay[..abs].chars().next_back().is_some_and(is_ident);
        let after_ok =
            !last_ident || !hay[abs + needle.len()..].chars().next().is_some_and(is_ident);
        if before_ok && after_ok {
            return Some(abs);
        }
        start = abs + needle.len().max(1);
    }
    None
}

fn contains_needle(hay: &str, needle: &str) -> bool {
    find_needle(hay, needle, 0).is_some()
}

// ---------------------------------------------------------------------------
// `#[cfg(test)]` region tracking.
// ---------------------------------------------------------------------------

/// Marks each line that falls inside a `#[cfg(test)]`-gated item's braces
/// (including the attribute line and the item header itself).
fn test_regions(scrubbed: &Scrubbed) -> Vec<bool> {
    let mut in_test = vec![false; scrubbed.code.len()];
    let mut depth: i64 = 0;
    // Brace depths at which a #[cfg(test)] item body opened.
    let mut stack: Vec<i64> = Vec::new();
    let mut pending_attr = false;

    for (li, line) in scrubbed.code.iter().enumerate() {
        let at_start = !stack.is_empty() || pending_attr;
        let attr_pos = line
            .find("cfg(test)")
            .or_else(|| line.find("cfg(all(test"));
        for (ci, c) in line.char_indices() {
            if let Some(p) = attr_pos {
                if ci == p {
                    pending_attr = true;
                }
            }
            match c {
                '{' => {
                    depth += 1;
                    if pending_attr {
                        stack.push(depth);
                        pending_attr = false;
                    }
                }
                '}' => {
                    if stack.last() == Some(&depth) {
                        stack.pop();
                    }
                    depth -= 1;
                }
                _ => {}
            }
        }
        in_test[li] = at_start || !stack.is_empty() || pending_attr;
    }
    in_test
}

// ---------------------------------------------------------------------------
// Rule implementations.
// ---------------------------------------------------------------------------

/// Allocation idioms banned inside `// lint: hot` fns.  `.collect` appears
/// twice to catch both call and turbofish forms.
const ALLOC_NEEDLES: &[&str] = &[
    "Vec::new",
    "vec!",
    ".to_vec(",
    ".collect(",
    ".collect::<",
    ".clone(",
    "Box::new",
    "format!",
];

fn is_comment_only(scrubbed: &Scrubbed, li: usize) -> bool {
    scrubbed.code[li].trim().is_empty() && !scrubbed.comment[li].trim().is_empty()
}

fn is_attr_only(scrubbed: &Scrubbed, li: usize) -> bool {
    let code = scrubbed.code[li].trim();
    code.starts_with("#[") || code.starts_with("#![")
}

/// True if line `li`'s `unsafe` is justified: a `SAFETY:` comment on the
/// same line, or in the contiguous run of comment/attribute lines directly
/// above (no blank-line gap), or — for `unsafe fn` declarations — a
/// `# Safety` doc section in that run.
fn has_safety_justification(scrubbed: &Scrubbed, li: usize, accept_doc: bool) -> bool {
    if scrubbed.comment[li].contains("SAFETY:") {
        return true;
    }
    let mut i = li;
    while i > 0 {
        i -= 1;
        if !(is_comment_only(scrubbed, i) || is_attr_only(scrubbed, i)) {
            break;
        }
        let com = &scrubbed.comment[i];
        if com.contains("SAFETY:") {
            return true;
        }
        if accept_doc && com.contains("# Safety") {
            return true;
        }
    }
    false
}

fn rule_unsafe_safety(
    rel: &str,
    raw: &[&str],
    scrubbed: &Scrubbed,
    in_test: &[bool],
    out: &mut Vec<Finding>,
) {
    for li in 0..scrubbed.code.len() {
        if in_test[li] {
            continue;
        }
        let line = &scrubbed.code[li];
        let Some(pos) = find_needle(line, "unsafe", 0) else {
            continue;
        };
        // `unsafe fn` declarations may justify via a `# Safety` doc section;
        // blocks and `unsafe impl` need an explicit `// SAFETY:`.
        let is_fn_decl = find_needle(line, "fn", pos).is_some();
        if !has_safety_justification(scrubbed, li, is_fn_decl) {
            let kind = if is_fn_decl {
                "unsafe fn without a `# Safety` doc section or `// SAFETY:` comment"
            } else {
                "unsafe block/impl without a `// SAFETY:` comment on or above it"
            };
            out.push(Finding {
                rule: Rule::UnsafeSafety,
                path: rel.to_string(),
                line: li + 1,
                message: kind.to_string(),
                raw_line: raw.get(li).copied().unwrap_or("").to_string(),
            });
        }
    }
}

/// Extracts the fn name following a `fn` keyword on `line`, for messages.
fn fn_name(line: &str) -> &str {
    let Some(pos) = find_needle(line, "fn", 0) else {
        return "?";
    };
    let rest = line[pos + 2..].trim_start();
    let end = rest
        .char_indices()
        .find(|(_, c)| !is_ident(*c))
        .map_or(rest.len(), |(i, _)| i);
    if end == 0 {
        "?"
    } else {
        &rest[..end]
    }
}

fn rule_hot_no_alloc(rel: &str, raw: &[&str], scrubbed: &Scrubbed, out: &mut Vec<Finding>) {
    let nlines = scrubbed.code.len();
    for li in 0..nlines {
        // Exact match on the trimmed comment text: prose that merely
        // *mentions* the marker (docs, this tool) must not arm the rule.
        if scrubbed.comment[li].trim() != "lint: hot" {
            continue;
        }
        // The annotated fn must start within the next few lines (doc
        // comments and attributes may intervene).
        let mut fn_line = None;
        for fi in li + 1..nlines.min(li + 11) {
            if find_needle(&scrubbed.code[fi], "fn", 0).is_some() {
                fn_line = Some(fi);
                break;
            }
        }
        let Some(fi) = fn_line else {
            out.push(Finding {
                rule: Rule::HotNoAlloc,
                path: rel.to_string(),
                line: li + 1,
                message: "dangling `// lint: hot` marker: no fn within 10 lines".to_string(),
                raw_line: raw.get(li).copied().unwrap_or("").to_string(),
            });
            continue;
        };
        let name = fn_name(&scrubbed.code[fi]).to_string();
        // Brace-match the fn body, then sweep it for allocation idioms.
        let mut depth: i64 = 0;
        let mut seen_open = false;
        for bi in fi..nlines {
            let line = &scrubbed.code[bi];
            for needle in ALLOC_NEEDLES {
                if contains_needle(line, needle) {
                    out.push(Finding {
                        rule: Rule::HotNoAlloc,
                        path: rel.to_string(),
                        line: bi + 1,
                        message: format!(
                            "allocation idiom `{needle}` in `// lint: hot` fn `{name}`"
                        ),
                        raw_line: raw.get(bi).copied().unwrap_or("").to_string(),
                    });
                }
            }
            for c in line.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        seen_open = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if seen_open && depth <= 0 {
                break;
            }
        }
    }
}

/// Binaries are exempt from `no-unwrap`: a CLI aborting on bad input is the
/// desired behavior there.
fn is_binary_path(rel: &str) -> bool {
    rel == "main.rs"
        || rel.ends_with("/main.rs")
        || rel.starts_with("bin/")
        || rel.contains("/bin/")
}

fn rule_no_unwrap(
    rel: &str,
    raw: &[&str],
    scrubbed: &Scrubbed,
    in_test: &[bool],
    out: &mut Vec<Finding>,
) {
    if is_binary_path(rel) {
        return;
    }
    for li in 0..scrubbed.code.len() {
        if in_test[li] {
            continue;
        }
        let line = &scrubbed.code[li];
        for needle in [".unwrap()", ".expect("] {
            if contains_needle(line, needle) {
                out.push(Finding {
                    rule: Rule::NoUnwrap,
                    path: rel.to_string(),
                    line: li + 1,
                    message: format!(
                        "`{needle}` in library code outside #[cfg(test)]; return a typed error \
                         or allowlist with a justification"
                    ),
                    raw_line: raw.get(li).copied().unwrap_or("").to_string(),
                });
            }
        }
    }
}

/// Paths where wall-clock reads are legitimate: the serving coordinator
/// (deadlines, metrics), the replica pool (shard maturity, steal
/// decisions, and deadline ejection all run on the serving clock), and
/// the bench harness.
fn wall_clock_allowed(rel: &str) -> bool {
    // `coordinator/pool.rs` is named on its own — it rides the blanket
    // coordinator/ exemption today, but the pool's clock reads are a
    // deliberate carve-out that must survive any future narrowing of
    // the prefix rule, so the exemption stays explicit.
    rel == "coordinator/pool.rs"
        || rel.ends_with("/coordinator/pool.rs")
        || rel.starts_with("coordinator/")
        || rel.contains("/coordinator/")
        || rel == "bench.rs"
        || rel.ends_with("/bench.rs")
        || rel.starts_with("benches/")
        || rel.contains("/benches/")
}

fn rule_no_wall_clock(
    rel: &str,
    raw: &[&str],
    scrubbed: &Scrubbed,
    in_test: &[bool],
    out: &mut Vec<Finding>,
) {
    if wall_clock_allowed(rel) {
        return;
    }
    for li in 0..scrubbed.code.len() {
        if in_test[li] {
            continue;
        }
        let line = &scrubbed.code[li];
        for needle in ["Instant::now", "SystemTime"] {
            if contains_needle(line, needle) {
                out.push(Finding {
                    rule: Rule::NoWallClock,
                    path: rel.to_string(),
                    line: li + 1,
                    message: format!(
                        "wall-clock read `{needle}` outside coordinator/ and benches breaks \
                         deterministic replay"
                    ),
                    raw_line: raw.get(li).copied().unwrap_or("").to_string(),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Public entry points.
// ---------------------------------------------------------------------------

/// Scans one file's source, returning raw (un-allowlisted) findings.
///
/// `rel` is the scan-root-relative, `/`-separated path; rule scoping
/// (binary exemption for `no-unwrap`, coordinator/bench exemption for
/// `no-wall-clock`) keys off it.
pub fn scan_source(rel: &str, source: &str) -> Vec<Finding> {
    let raw: Vec<&str> = source.split('\n').collect();
    let scrubbed = scrub(source);
    let in_test = test_regions(&scrubbed);
    let mut out = Vec::new();
    rule_unsafe_safety(rel, &raw, &scrubbed, &in_test, &mut out);
    rule_hot_no_alloc(rel, &raw, &scrubbed, &mut out);
    rule_no_unwrap(rel, &raw, &scrubbed, &in_test, &mut out);
    rule_no_wall_clock(rel, &raw, &scrubbed, &in_test, &mut out);
    out.sort_by(|a, b| (a.line, a.rule.id()).cmp(&(b.line, b.rule.id())));
    out
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Scans every `.rs` file under `root` (recursively, sorted order).
pub fn scan_tree(root: &Path) -> io::Result<TreeScan> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f)?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f.as_path())
            .to_string_lossy()
            .replace('\\', "/");
        findings.extend(scan_source(&rel, &src));
    }
    Ok(TreeScan {
        files: files.len(),
        findings,
    })
}

/// Parses `allow.list` text: one `rule-id path-suffix line-substring` entry
/// per line; `#` comments and blank lines skipped.  The substring is the
/// rest of the line and may contain spaces.
pub fn parse_allowlist(text: &str) -> Vec<AllowEntry> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((rule, rest)) = split_once_ws(line) else {
            continue;
        };
        let Some((path_suffix, needle)) = split_once_ws(rest) else {
            continue;
        };
        out.push(AllowEntry {
            rule: rule.to_string(),
            path_suffix: path_suffix.to_string(),
            needle: needle.to_string(),
        });
    }
    out
}

fn split_once_ws(s: &str) -> Option<(&str, &str)> {
    let idx = s.find(char::is_whitespace)?;
    Some((&s[..idx], s[idx..].trim_start()))
}

/// Filters findings through the allowlist.  Returns the surviving findings
/// plus a per-entry use count (zero means the entry is stale).
pub fn apply_allowlist(
    findings: Vec<Finding>,
    allow: &[AllowEntry],
) -> (Vec<Finding>, Vec<usize>) {
    let mut used = vec![0usize; allow.len()];
    let kept = findings
        .into_iter()
        .filter(|f| {
            for (i, e) in allow.iter().enumerate() {
                if e.rule == f.rule.id()
                    && f.path.ends_with(&e.path_suffix)
                    && f.raw_line.contains(&e.needle)
                {
                    used[i] += 1;
                    return false;
                }
            }
            true
        })
        .collect();
    (kept, used)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrub_masks_comments_and_strings() {
        let src = "let a = \"unsafe .unwrap()\"; // SAFETY: not code\nlet b = 1;";
        let s = scrub(src);
        assert!(!s.code[0].contains("unwrap"));
        assert!(!s.code[0].contains("SAFETY"));
        assert!(s.comment[0].contains("SAFETY: not code"));
        assert_eq!(s.code[1].trim(), "let b = 1;");
    }

    #[test]
    fn scrub_handles_lifetimes_and_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { '\\'' }";
        let s = scrub(src);
        // Lifetimes survive as code; the char literal is blanked.
        assert!(s.code[0].contains("<'a>"));
        assert!(!s.code[0].contains("\\'"));
    }

    #[test]
    fn scrub_handles_raw_strings() {
        let src = "let r = r#\"has .unwrap() inside\"#;\nlet x = y.unwrap();";
        let s = scrub(src);
        assert!(!s.code[0].contains("unwrap"));
        assert!(s.code[1].contains(".unwrap()"));
    }

    #[test]
    fn test_region_tracking() {
        let src = "fn a() { b.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { c.unwrap(); }\n}\nfn d() {}\n";
        let s = scrub(src);
        let in_test = test_regions(&s);
        assert!(!in_test[0]);
        assert!(in_test[1]);
        assert!(in_test[2]);
        assert!(in_test[3]);
        assert!(!in_test[5]);
    }

    #[test]
    fn needle_boundaries() {
        assert!(contains_needle("let t = Instant::now();", "Instant::now"));
        assert!(!contains_needle("let t = MyInstant::nowish();", "Instant::now"));
        assert!(contains_needle("x.unwrap()", ".unwrap()"));
        assert!(!contains_needle("x.unwrap_or(0)", ".unwrap()"));
    }

    #[test]
    fn allowlist_round_trip() {
        let allow = parse_allowlist(
            "# comment\n\nno-unwrap nn/graph.rs try_into().unwrap()\n",
        );
        assert_eq!(allow.len(), 1);
        assert_eq!(allow[0].rule, "no-unwrap");
        assert_eq!(allow[0].path_suffix, "nn/graph.rs");
        assert_eq!(allow[0].needle, "try_into().unwrap()");
        let findings = scan_source("nn/graph.rs", "fn f() { let x = b.try_into().unwrap(); }\n");
        assert_eq!(findings.len(), 1);
        let (kept, used) = apply_allowlist(findings, &allow);
        assert!(kept.is_empty());
        assert_eq!(used, vec![1]);
    }
}
