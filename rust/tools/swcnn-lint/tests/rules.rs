//! Proves every swcnn-lint rule is live: each fixture must fire at
//! exactly the expected lines (located by content, so fixtures can be
//! edited without renumbering), negative cases must stay silent, and
//! the real `rust/src` tree must scan clean under `allow.list` with no
//! stale entries.

use std::fs;
use std::path::Path;

use swcnn_lint::{apply_allowlist, parse_allowlist, scan_source, scan_tree, Finding, Rule};

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

/// 1-based line of the first line containing `needle`.
fn line_of(src: &str, needle: &str) -> usize {
    src.lines()
        .position(|l| l.contains(needle))
        .unwrap_or_else(|| panic!("fixture lacks {needle:?}"))
        + 1
}

fn lines_for(findings: &[Finding], rule: Rule) -> Vec<usize> {
    findings
        .iter()
        .filter(|f| f.rule == rule)
        .map(|f| f.line)
        .collect()
}

#[test]
fn unsafe_safety_fires_on_unjustified_sites_only() {
    let src = fixture("unsafe_no_safety.rs");
    let findings = scan_source("winograd/fixture.rs", &src);
    let want = vec![
        line_of(&src, "pub fn bare") + 1, // the bare `unsafe { *p }` body line
        line_of(&src, "pub unsafe fn undocumented"),
    ];
    assert_eq!(lines_for(&findings, Rule::UnsafeSafety), want, "{findings:#?}");
}

#[test]
fn hot_no_alloc_fires_inside_hot_fns_only() {
    let src = fixture("hot_alloc.rs");
    let findings = scan_source("winograd/fixture.rs", &src);
    let want = vec![
        line_of(&src, "vec![0.0f32; n];"), // inside hot_allocates
        line_of(&src, "v.clone()"),
        src.trim_end().lines().count(), // the trailing dangling marker
    ];
    assert_eq!(lines_for(&findings, Rule::HotNoAlloc), want, "{findings:#?}");
    let msgs: Vec<&str> = findings
        .iter()
        .filter(|f| f.rule == Rule::HotNoAlloc)
        .map(|f| f.message.as_str())
        .collect();
    assert!(msgs[0].contains("vec!") && msgs[0].contains("hot_allocates"), "{msgs:?}");
    assert!(msgs[2].contains("dangling"), "{msgs:?}");
}

#[test]
fn no_unwrap_fires_outside_tests_and_respects_boundaries() {
    let src = fixture("unwrap.rs");
    let findings = scan_source("nn/fixture.rs", &src);
    let want = vec![line_of(&src, "x.unwrap()"), line_of(&src, "x.expect(")];
    assert_eq!(lines_for(&findings, Rule::NoUnwrap), want, "{findings:#?}");
}

#[test]
fn no_unwrap_exempts_binaries() {
    let src = fixture("unwrap.rs");
    for rel in ["main.rs", "bin/swcnn-cli.rs", "tools/bin/gen.rs"] {
        let findings = scan_source(rel, &src);
        assert!(
            lines_for(&findings, Rule::NoUnwrap).is_empty(),
            "{rel} must be exempt: {findings:#?}"
        );
    }
}

#[test]
fn no_wall_clock_fires_outside_coordinator_and_benches() {
    let src = fixture("wall_clock.rs");
    let findings = scan_source("model/fixture.rs", &src);
    let want = vec![
        line_of(&src, "Instant::now();"),
        line_of(&src, "SystemTime::now();"),
    ];
    assert_eq!(lines_for(&findings, Rule::NoWallClock), want, "{findings:#?}");
    for rel in [
        "coordinator/server.rs",
        "coordinator/pool.rs",
        "src/coordinator/pool.rs",
        "bench.rs",
        "benches/e2e.rs",
    ] {
        let findings = scan_source(rel, &src);
        assert!(
            lines_for(&findings, Rule::NoWallClock).is_empty(),
            "{rel} must be exempt: {findings:#?}"
        );
    }
}

#[test]
fn allowlist_suppresses_by_rule_path_and_substring() {
    let src = fixture("unwrap.rs");
    let allow = parse_allowlist("no-unwrap nn/fixture.rs x.unwrap()\n");
    let (kept, used) = apply_allowlist(scan_source("nn/fixture.rs", &src), &allow);
    assert_eq!(used, vec![1]);
    // The `.expect(` finding survives: the entry only covers `.unwrap()`.
    assert_eq!(kept.len(), 1, "{kept:#?}");
    assert_eq!(kept[0].line, line_of(&src, "x.expect("));
    // Same entry against a different path suppresses nothing.
    let (kept, used) = apply_allowlist(scan_source("tuner/fixture.rs", &src), &allow);
    assert_eq!(used, vec![0]);
    assert_eq!(kept.len(), 2, "{kept:#?}");
}

/// The self-check the CLI runs in CI: the real library tree must be
/// clean under the checked-in allowlist, and every allowlist entry must
/// still be earning its keep.
#[test]
fn live_tree_scans_clean_under_allowlist() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = manifest.join("../../src");
    let scan = scan_tree(&root).expect("scan rust/src");
    assert!(
        scan.files >= 30,
        "expected the full library tree under {}, scanned only {} files",
        root.display(),
        scan.files
    );
    let allow_text =
        fs::read_to_string(manifest.join("allow.list")).expect("read allow.list");
    let allow = parse_allowlist(&allow_text);
    for e in &allow {
        assert!(
            Rule::from_id(&e.rule).is_some(),
            "allow.list names unknown rule {:?}",
            e.rule
        );
    }
    let (kept, used) = apply_allowlist(scan.findings, &allow);
    assert!(
        kept.is_empty(),
        "rust/src has un-allowlisted findings:\n{kept:#?}"
    );
    for (e, u) in allow.iter().zip(&used) {
        assert!(*u > 0, "stale allow.list entry (no longer matches): {e:?}");
    }
}
