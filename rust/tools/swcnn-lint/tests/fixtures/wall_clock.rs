// Fixture for the `no-wall-clock` rule.  Not compiled — scanned by
// tests/rules.rs, which asserts exactly which lines fire.

pub fn measure() -> std::time::Duration {
    let t0 = std::time::Instant::now();
    t0.elapsed()
}

pub fn stamp() -> u64 {
    let now = std::time::SystemTime::now();
    now.elapsed().map(|d| d.as_secs()).unwrap_or(0)
}

// A comment mentioning Instant::now or SystemTime must not fire.

pub fn look_alikes_are_ignored() {
    struct MySystemTimeish;
    let _ = MySystemTimeish;
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_time() {
        let _ = std::time::Instant::now();
    }
}
