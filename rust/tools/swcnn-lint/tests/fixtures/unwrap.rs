// Fixture for the `no-unwrap` rule.  Not compiled — scanned by
// tests/rules.rs, which asserts exactly which lines fire.

pub fn lib_unwrap(x: Option<u32>) -> u32 {
    x.unwrap()
}

pub fn lib_expect(x: Option<u32>) -> u32 {
    x.expect("boom")
}

pub fn boundary_is_respected(x: Option<u32>) -> u32 {
    x.unwrap_or(0)
}

pub fn string_literal_is_ignored() -> &'static str {
    "calling .unwrap() here is just prose"
}

// so is a comment mentioning .unwrap() or .expect(...)

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        Some(1u32).unwrap();
        Some(2u32).expect("fine in tests");
    }
}
