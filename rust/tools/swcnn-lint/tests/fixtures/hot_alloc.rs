// Fixture for the `hot-no-alloc` rule.  Not compiled — scanned by
// tests/rules.rs, which asserts exactly which lines fire.

// lint: hot
pub fn hot_allocates(n: usize) -> Vec<f32> {
    let v = vec![0.0f32; n];
    let w = v.clone();
    w
}

// lint: hot
#[inline]
pub fn hot_clean(out: &mut [f32], scale: f32) {
    for v in out.iter_mut() {
        *v *= scale;
    }
}

pub fn cold_allocates(n: usize) -> Vec<f32> {
    vec![0.0f32; n]
}

// Prose that merely mentions the lint: hot marker must not arm the rule.
pub fn prose_mention(n: usize) -> Vec<usize> {
    (0..n).collect()
}

// lint: hot
