// Fixture for the `unsafe-safety` rule.  Not compiled — scanned by
// tests/rules.rs, which asserts exactly which lines fire.

pub fn justified(p: *const u8) -> u8 {
    // SAFETY: the caller guarantees `p` is valid for reads.
    unsafe { *p }
}

pub fn bare(p: *const u8) -> u8 {
    unsafe { *p }
}

/// Reads a byte through `p`.
///
/// # Safety
///
/// `p` must be valid for reads.
pub unsafe fn documented(p: *const u8) -> u8 {
    // SAFETY: forwarded from the fn-level contract above.
    unsafe { *p }
}

pub unsafe fn undocumented(p: *const u8) -> u8 {
    // SAFETY: forwarded (justifies this inner block, not the bare decl).
    unsafe { *p }
}

pub fn prose_only() -> &'static str {
    "this string mentions unsafe but is not code"
}
