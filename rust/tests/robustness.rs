//! Fault-tolerance proof suite for the serving pipeline.
//!
//! Every test drives the *real* server — admission queue, batcher,
//! supervisor, engine — through a deterministic [`FaultPlan`] and
//! asserts the three contracts from the robustness redesign:
//!
//! 1. **No silent drops**: every admitted request receives exactly one
//!    completion (logits or a typed error), under injected panics,
//!    deadline storms, queue-full bursts, worker death, and shutdown.
//! 2. **Bit-identical recovery**: a restarted worker serves outputs
//!    identical to a fault-free run.
//! 3. **Deadline ejection is pre-dispatch**: expired requests never
//!    occupy a fused batch slot (visible in the batch histogram).
//!
//! All schedules are seeded — a failing run replays exactly.  The
//! `stress_supervisor_restart_100x` test (`--ignored`; CI's stress
//! smoke) writes `FAULT_stress.log` via [`render_log`] on failure.

#![cfg(feature = "fault-injection")]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Once};
use std::time::{Duration, Instant};

use swcnn::coordinator::{
    render_log, AdmissionError, AdmissionPolicy, FaultEvent, FaultPlan, InferenceServer,
    RestartPolicy, ServeBuilder, ServeError,
};
use swcnn::executor::{ExecPolicy, Session};
use swcnn::nn::graph::{GraphBuilder, GraphError, Synthetic};
use swcnn::util::Rng;

const IN_ELEMS: usize = 2 * 8 * 8;
const OUT_ELEMS: usize = 3;

/// Silence the default panic hook for *injected* panics (their payloads
/// carry the "fault-injection" marker); genuine panics still print.
fn quiet_injected_panics() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .unwrap_or("");
            if !msg.contains("fault-injection") {
                prev(info);
            }
        }));
    });
}

/// A graph small enough that a faulted batch costs microseconds, with
/// every op class the serving path exercises.
fn tiny_session() -> Session {
    let g = GraphBuilder::new("tiny", (2, 8, 8))
        .pad(1)
        .conv2d("c0", 4, 3)
        .relu()
        .maxpool2()
        .flatten()
        .fc("head", OUT_ELEMS)
        .build()
        .expect("tiny graph builds");
    Session::uniform(g, &mut Synthetic::new(3), ExecPolicy::dense(2)).expect("tiny compiles")
}

/// Fast restart policy so faulted tests stay in the milliseconds.
fn fast_restart() -> RestartPolicy {
    RestartPolicy {
        breaker_threshold: 1000, // breaker out of the way unless a test wants it
        backoff_base: Duration::from_micros(200),
        backoff_max: Duration::from_millis(2),
        breaker_cooldown: Duration::from_millis(50),
    }
}

fn tiny_cfg() -> ServeBuilder {
    ServeBuilder::new(tiny_session())
        .restart(fast_restart())
        .max_batch(4)
}

fn image(seed: u64) -> Vec<f32> {
    Rng::new(seed).gaussian_vec(IN_ELEMS)
}

/// Block until the worker has pulled everything queued into a dispatch
/// (the timing-sensitive tests use this instead of fixed sleeps, so a
/// slow runner cannot let a "stalling" batch absorb later traffic).
fn wait_queue_drained(server: &InferenceServer) {
    let t0 = Instant::now();
    while server.queue_depth() > 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "worker never picked up the queued batch"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

// ---------------------------------------------------------------------------
// Contract 1: exactly one completion per admitted request
// ---------------------------------------------------------------------------

/// The no-silent-drop proof: concurrent bursts against a tiny bounded
/// queue, with a random (seeded) panic schedule underneath, short
/// deadlines on part of the traffic, and a drain at the end.  Every
/// call either refuses synchronously or yields exactly one completion;
/// nothing hangs and nothing completes twice.
#[test]
fn every_admission_gets_exactly_one_completion() {
    quiet_injected_panics();
    let plan = FaultPlan::seeded(42).with_random_panics(64, 0.3);
    let bursts = plan.burst_sizes(6, 5);
    let server = Arc::new(
        tiny_cfg()
            .queue(8, AdmissionPolicy::RejectNew)
            .fault_plan(plan)
            .window(Duration::from_micros(500))
            .start()
            .expect("start"),
    );

    let admitted = Arc::new(AtomicU64::new(0));
    let refused = Arc::new(AtomicU64::new(0));
    let completed_ok = Arc::new(AtomicU64::new(0));
    let completed_err = Arc::new(AtomicU64::new(0));
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let server = Arc::clone(&server);
            let bursts = bursts.clone();
            let admitted = Arc::clone(&admitted);
            let refused = Arc::clone(&refused);
            let completed_ok = Arc::clone(&completed_ok);
            let completed_err = Arc::clone(&completed_err);
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for (round, &burst) in bursts.iter().enumerate() {
                    let mut replies = Vec::new();
                    for i in 0..burst {
                        // Every third request carries a tight deadline so
                        // the storm also exercises pre-dispatch ejection.
                        let deadline = if i % 3 == 0 {
                            Some(Duration::from_micros(300))
                        } else {
                            None
                        };
                        match server.infer_async_deadline(rng.gaussian_vec(IN_ELEMS), deadline) {
                            Ok(rx) => {
                                admitted.fetch_add(1, Ordering::SeqCst);
                                replies.push(rx);
                            }
                            Err(
                                AdmissionError::QueueFull { .. }
                                | AdmissionError::CircuitOpen { .. },
                            ) => {
                                refused.fetch_add(1, Ordering::SeqCst);
                            }
                            Err(e) => panic!("unexpected synchronous refusal: {e}"),
                        }
                    }
                    for rx in replies {
                        // A hang here IS the bug this suite exists for.
                        let result = rx
                            .recv_timeout(Duration::from_secs(30))
                            .expect("admitted request must complete, never hang");
                        match result {
                            Ok(y) => {
                                assert_eq!(y.len(), OUT_ELEMS);
                                completed_ok.fetch_add(1, Ordering::SeqCst);
                            }
                            Err(
                                AdmissionError::WorkerFault { .. }
                                | AdmissionError::DeadlineExpired { .. }
                                | AdmissionError::QueueFull { .. }
                                | AdmissionError::ShuttingDown,
                            ) => {
                                completed_err.fetch_add(1, Ordering::SeqCst);
                            }
                            Err(e) => panic!("untyped completion: {e}"),
                        }
                        // Exactly one: the channel must now be dead or empty.
                        assert!(
                            rx.try_recv().is_err(),
                            "round {round}: a request completed twice"
                        );
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().expect("load thread");
    }

    let admitted = admitted.load(Ordering::SeqCst);
    let refused = refused.load(Ordering::SeqCst);
    let done = completed_ok.load(Ordering::SeqCst) + completed_err.load(Ordering::SeqCst);
    assert_eq!(done, admitted, "every admission completes exactly once");
    assert!(admitted > 0, "the load must actually admit something");

    // The robustness counters were exercised and show up in summary().
    let m = server.metrics.lock().unwrap();
    assert!(m.queue_depth_peak >= 1);
    // breaker_threshold is parked at 1000, so every synchronous refusal
    // was a QueueFull — and each one was counted.
    assert_eq!(m.rejected_full, refused);
    let s = m.summary();
    for key in [
        "rejected_full=",
        "ejected_deadline=",
        "worker_faults=",
        "queue_depth_peak=",
    ] {
        assert!(s.contains(key), "summary missing {key}: {s}");
    }
}

// ---------------------------------------------------------------------------
// Contract 2: supervised restart, bit-identical recovery
// ---------------------------------------------------------------------------

#[test]
fn supervisor_restarts_panicked_worker_bit_identically() {
    quiet_injected_panics();
    let x = image(7);
    let clean = tiny_cfg().start().expect("start clean");
    let want = clean.infer(x.clone()).expect("fault-free serve");

    let server = tiny_cfg()
        .fault_plan(FaultPlan::seeded(1).panic_on_batch(1))
        .start()
        .expect("start faulty");
    let first = server.infer(x.clone()).expect("batch 0 serves");
    assert_eq!(first, want, "pre-fault output matches the clean server");
    let err = server.infer(x.clone()).unwrap_err();
    assert!(
        matches!(err, AdmissionError::WorkerFault { .. }),
        "the poisoned batch fails typed, got {err:?}"
    );
    let after = server.infer(x).expect("post-restart serve");
    assert_eq!(after, want, "recovery must be bit-identical");

    let events = server.fault_events();
    assert!(events
        .iter()
        .any(|e| matches!(e, FaultEvent::InjectedPanic { batch: 1 })));
    assert!(events
        .iter()
        .any(|e| matches!(e, FaultEvent::CaughtPanic { batch: 1, .. })));
    assert!(events
        .iter()
        .any(|e| matches!(e, FaultEvent::Restarted { incarnation: 1, .. })));
    let m = server.metrics.lock().unwrap();
    assert_eq!(m.worker_faults, 1);
}

#[test]
fn breaker_trips_after_consecutive_faults_and_recovers() {
    quiet_injected_panics();
    let mut restart = fast_restart();
    restart.breaker_threshold = 2;
    restart.breaker_cooldown = Duration::from_millis(150);
    let server = tiny_cfg()
        .restart(restart)
        .fault_plan(FaultPlan::seeded(5).panic_on_batch(0).panic_on_batch(1))
        .start()
        .expect("start");
    let x = image(9);

    for _ in 0..2 {
        let err = server.infer(x.clone()).unwrap_err();
        assert!(matches!(err, AdmissionError::WorkerFault { .. }), "{err:?}");
    }
    assert!(server.breaker_open(), "two consecutive faults trip it");
    match server.infer_async(x.clone()) {
        Err(AdmissionError::CircuitOpen { consecutive_faults }) => {
            assert!(consecutive_faults >= 2)
        }
        other => panic!("open breaker must fast-fail admission, got {other:?}"),
    }

    // Half-open after the cooldown: a probe flows, succeeds (batch 2 is
    // not scheduled to panic), and closes the breaker.
    std::thread::sleep(Duration::from_millis(200));
    let y = server.infer(x).expect("probe serves after cooldown");
    assert_eq!(y.len(), OUT_ELEMS);
    assert!(!server.breaker_open(), "a served batch closes the breaker");
    let events = server.fault_events();
    assert!(events
        .iter()
        .any(|e| matches!(e, FaultEvent::BreakerTripped { consecutive: 2 })));
    assert!(events.iter().any(|e| matches!(e, FaultEvent::BreakerClosed)));
}

// ---------------------------------------------------------------------------
// Contract 3: deadlines eject before batch assembly
// ---------------------------------------------------------------------------

#[test]
fn expired_requests_never_occupy_a_fused_batch_slot() {
    quiet_injected_panics();
    // Batch 0 stalls 300ms; four short-deadline requests pile up behind
    // it, expire while it crawls, and must be ejected at the next
    // assembly — visible as: one batch of 1, zero batches of 4.
    let server = tiny_cfg()
        .fault_plan(FaultPlan::seeded(2).latency_on_batch(0, Duration::from_millis(300)))
        .window(Duration::ZERO)
        .start()
        .expect("start");

    let slow = server.infer_async(image(1)).expect("admitted");
    // Once the queue drains, batch 0's membership is sealed — the worker
    // is inside (or entering) the 300ms stall with exactly one slot used.
    wait_queue_drained(&server);
    let doomed: Vec<_> = (0..4)
        .map(|i| {
            server
                .infer_async_deadline(image(2 + i), Some(Duration::from_millis(30)))
                .expect("admitted")
        })
        .collect();
    for rx in doomed {
        match rx.recv_timeout(Duration::from_secs(10)).expect("completes") {
            Err(AdmissionError::DeadlineExpired { deadline, waited }) => {
                assert_eq!(deadline, Duration::from_millis(30));
                assert!(waited > deadline, "ejection reports the real wait");
            }
            other => panic!("expired request must eject, got {other:?}"),
        }
    }
    let y = slow
        .recv_timeout(Duration::from_secs(10))
        .expect("completes")
        .expect("slow batch still serves");
    assert_eq!(y.len(), OUT_ELEMS);

    let m = server.metrics.lock().unwrap();
    assert_eq!(m.ejected_deadline, 4, "all four ejected");
    assert_eq!(m.batches, 1, "only the stalled batch ever dispatched");
    assert_eq!(m.batch_histogram()[1], 1);
    assert_eq!(
        m.batch_histogram()[4],
        0,
        "expired requests must never form a fused batch"
    );
}

// ---------------------------------------------------------------------------
// Bounded admission
// ---------------------------------------------------------------------------

#[test]
fn full_queue_rejects_new_requests_synchronously() {
    quiet_injected_panics();
    let server = tiny_cfg()
        .queue(2, AdmissionPolicy::RejectNew)
        .fault_plan(FaultPlan::seeded(3).latency_every_batch(Duration::from_millis(250)))
        .window(Duration::ZERO)
        .start()
        .expect("start");

    let in_flight = server.infer_async(image(1)).expect("admitted");
    wait_queue_drained(&server); // worker now stalled in batch 0
    let queued: Vec<_> = (0..2)
        .map(|i| server.infer_async(image(2 + i)).expect("fills the queue"))
        .collect();
    assert_eq!(server.queue_depth(), 2);
    match server.infer_async(image(9)) {
        Err(AdmissionError::QueueFull { capacity: 2 }) => {}
        other => panic!("full queue must refuse, got {other:?}"),
    }
    for rx in std::iter::once(in_flight).chain(queued) {
        let y = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("completes")
            .expect("admitted requests still serve");
        assert_eq!(y.len(), OUT_ELEMS);
    }
    let m = server.metrics.lock().unwrap();
    assert_eq!(m.rejected_full, 1);
}

#[test]
fn full_queue_drop_oldest_evicts_the_stalest_request() {
    quiet_injected_panics();
    let server = tiny_cfg()
        .queue(2, AdmissionPolicy::DropOldest)
        .fault_plan(FaultPlan::seeded(4).latency_every_batch(Duration::from_millis(250)))
        .window(Duration::ZERO)
        .start()
        .expect("start");

    let in_flight = server.infer_async(image(1)).expect("admitted");
    wait_queue_drained(&server); // worker now stalled in batch 0
    let oldest = server.infer_async(image(2)).expect("admitted");
    let kept = server.infer_async(image(3)).expect("admitted");
    // Queue is at capacity (2); the next admission evicts `oldest`,
    // which must still complete — with a typed QueueFull, not silence.
    let freshest = server.infer_async(image(4)).expect("admitted over eviction");
    match oldest.recv_timeout(Duration::from_secs(10)).expect("completes") {
        Err(AdmissionError::QueueFull { capacity: 2 }) => {}
        other => panic!("evicted request must complete with QueueFull, got {other:?}"),
    }
    for rx in [in_flight, kept, freshest] {
        let y = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("completes")
            .expect("surviving requests serve");
        assert_eq!(y.len(), OUT_ELEMS);
    }
    let m = server.metrics.lock().unwrap();
    assert_eq!(m.rejected_full, 1, "the eviction is counted");
}

// ---------------------------------------------------------------------------
// Shutdown semantics
// ---------------------------------------------------------------------------

#[test]
fn shutdown_drains_or_rejects_deterministically() {
    quiet_injected_panics();
    // Reject-shutdown: in-flight work finishes, queued work completes
    // with ShuttingDown, new admissions refuse synchronously.
    let server = tiny_cfg()
        .fault_plan(FaultPlan::seeded(6).latency_every_batch(Duration::from_millis(250)))
        .window(Duration::ZERO)
        .start()
        .expect("start");
    let in_flight = server.infer_async(image(1)).expect("admitted");
    wait_queue_drained(&server); // worker now stalled in batch 0
    let queued: Vec<_> = (0..3)
        .map(|i| server.infer_async(image(2 + i)).expect("admitted"))
        .collect();
    server.shutdown(false);
    assert_eq!(
        server.infer_async(image(9)).unwrap_err(),
        AdmissionError::ShuttingDown
    );
    in_flight
        .recv_timeout(Duration::from_secs(10))
        .expect("completes")
        .expect("in-flight batch still serves");
    for rx in queued {
        match rx.recv_timeout(Duration::from_secs(10)).expect("completes") {
            Err(AdmissionError::ShuttingDown) => {}
            other => panic!("queued request under reject-shutdown: {other:?}"),
        }
    }

    // Drain-shutdown: everything queued serves.
    let server = tiny_cfg().start().expect("start");
    let queued: Vec<_> = (0..3)
        .map(|i| server.infer_async(image(20 + i)).expect("admitted"))
        .collect();
    server.shutdown(true);
    for rx in queued {
        let y = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("completes")
            .expect("drain serves queued work");
        assert_eq!(y.len(), OUT_ELEMS);
    }
}

/// Satellite regression: a request admitted just before shutdown must
/// flush immediately — the drain bypasses the batching window instead
/// of sitting it out.
#[test]
fn drain_bypasses_the_batching_window() {
    quiet_injected_panics();
    let server = tiny_cfg()
        .window(Duration::from_secs(5))
        .max_batch(4)
        .start()
        .expect("start");
    let rx = server.infer_async(image(1)).expect("admitted");
    let start = Instant::now();
    server.shutdown(true);
    let y = rx
        .recv_timeout(Duration::from_secs(2))
        .expect("a drained request must not wait out a 5s window")
        .expect("serves");
    assert_eq!(y.len(), OUT_ELEMS);
    assert!(
        start.elapsed() < Duration::from_secs(2),
        "flush must be immediate, waited {:?}",
        start.elapsed()
    );
}

// ---------------------------------------------------------------------------
// Worker death (the pre-supervisor hang bug, now typed)
// ---------------------------------------------------------------------------

/// Satellite regression: before the redesign, a dead worker left
/// `infer` blocked on (or erroring uselessly from) a disconnected
/// channel.  An injected *kill* panics outside the supervisor's catch
/// scope — the thread genuinely dies — and every caller must still get
/// a typed `WorkerFault`, promptly.
#[test]
fn worker_death_is_a_typed_error_not_a_hang() {
    quiet_injected_panics();
    let server = tiny_cfg()
        .fault_plan(FaultPlan::seeded(8).kill_on_batch(0))
        .start()
        .expect("start");
    let rx = server.infer_async(image(1)).expect("admitted");
    match rx.recv_timeout(Duration::from_secs(10)) {
        Ok(Err(AdmissionError::WorkerFault { msg })) => {
            assert!(msg.contains("died"), "{msg}")
        }
        other => panic!("in-flight request on worker death: {other:?}"),
    }
    // The death is journaled and subsequent calls refuse synchronously.
    assert!(server
        .fault_events()
        .iter()
        .any(|e| matches!(e, FaultEvent::WorkerDied)));
    match server.infer(image(2)) {
        Err(AdmissionError::WorkerFault { .. }) => {}
        other => panic!("dead server must refuse typed, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Error surfaces (table-driven Display / source chains)
// ---------------------------------------------------------------------------

#[test]
fn every_error_variant_renders_a_useful_chain() {
    use std::error::Error as _;
    let graph_errors: Vec<(GraphError, &str)> = vec![
        (
            GraphError::Shape {
                node: 2,
                msg: "bad".into(),
            },
            "node 2",
        ),
        (GraphError::Policy("m out of range".into()), "ExecPolicy"),
        (
            GraphError::PolicyCount {
                expected: 3,
                got: 1,
            },
            "3 conv nodes",
        ),
        (
            GraphError::Input {
                index: 0,
                expected: 128,
                got: 7,
            },
            "expected 128",
        ),
        (GraphError::EmptyBatch, "at least one image"),
        (
            GraphError::BatchTooLarge { got: 9, max: 4 },
            "workspace capacity 4",
        ),
        (GraphError::Weights("short tensor".into()), "weight source"),
        (GraphError::Io("no such file".into()), "weight file"),
        (GraphError::Config("bad profile".into()), "configuration"),
        (GraphError::Panic("boom".into()), "poisoned"),
        (GraphError::Poisoned, "reset_workspace"),
    ];
    for (e, needle) in &graph_errors {
        let shown = e.to_string();
        assert!(shown.contains(needle), "{e:?} renders {shown:?}");
        assert!(e.source().is_none(), "GraphError is a leaf");
    }

    let admission_errors: Vec<(AdmissionError, &str)> = vec![
        (AdmissionError::QueueFull { capacity: 8 }, "capacity 8"),
        (AdmissionError::ShuttingDown, "shutting down"),
        (
            AdmissionError::DeadlineExpired {
                deadline: Duration::from_millis(5),
                waited: Duration::from_millis(9),
            },
            "before dispatch",
        ),
        (
            AdmissionError::CircuitOpen {
                consecutive_faults: 3,
            },
            "circuit breaker open",
        ),
        (
            AdmissionError::WorkerFault { msg: "boom".into() },
            "worker fault",
        ),
        (
            AdmissionError::Engine(GraphError::EmptyBatch),
            "engine refused",
        ),
    ];
    for (e, needle) in &admission_errors {
        let shown = e.to_string();
        assert!(shown.contains(needle), "{e:?} renders {shown:?}");
        match e {
            AdmissionError::Engine(inner) => {
                let src = e.source().expect("Engine carries its cause");
                assert_eq!(src.to_string(), inner.to_string());
            }
            _ => assert!(e.source().is_none(), "{e:?} is a leaf"),
        }
    }
    // The table is exhaustive: adding a variant without a row here must
    // fail loudly.
    assert_eq!(graph_errors.len(), 11);
    assert_eq!(admission_errors.len(), 6);
}

/// The unified wire-facing error surface: every `ServeError` carries a
/// **stable** numeric code the network protocol ships verbatim.  This
/// table pins every assigned code and its `PROTOCOL.md` name — a
/// renumbering, a collision, or a nameless code fails here, not in a
/// remote client's error handler.
#[test]
fn serve_error_codes_are_stable_and_collision_free() {
    use std::error::Error as _;
    let table: Vec<(ServeError, u16, &str)> = vec![
        (
            AdmissionError::QueueFull { capacity: 8 }.into(),
            1,
            "queue_full",
        ),
        (AdmissionError::ShuttingDown.into(), 2, "shutting_down"),
        (
            AdmissionError::DeadlineExpired {
                deadline: Duration::from_millis(5),
                waited: Duration::from_millis(9),
            }
            .into(),
            3,
            "deadline_expired",
        ),
        (
            AdmissionError::CircuitOpen {
                consecutive_faults: 3,
            }
            .into(),
            4,
            "circuit_open",
        ),
        (
            AdmissionError::WorkerFault { msg: "boom".into() }.into(),
            5,
            "worker_fault",
        ),
        (
            GraphError::Shape {
                node: 2,
                msg: "bad".into(),
            }
            .into(),
            16,
            "graph_shape",
        ),
        (GraphError::Policy("m".into()).into(), 17, "graph_policy"),
        (
            GraphError::PolicyCount {
                expected: 3,
                got: 1,
            }
            .into(),
            18,
            "graph_policy_count",
        ),
        (
            GraphError::Input {
                index: 0,
                expected: 128,
                got: 7,
            }
            .into(),
            19,
            "graph_input",
        ),
        (
            GraphError::Output {
                expected: 3,
                got: 1,
            }
            .into(),
            20,
            "graph_output",
        ),
        (GraphError::EmptyBatch.into(), 21, "graph_empty_batch"),
        (
            GraphError::BatchTooLarge { got: 9, max: 4 }.into(),
            22,
            "graph_batch_too_large",
        ),
        (GraphError::Weights("w".into()).into(), 23, "graph_weights"),
        (GraphError::Io("f".into()).into(), 24, "graph_io"),
        (GraphError::Config("c".into()).into(), 25, "graph_config"),
        (GraphError::Panic("p".into()).into(), 26, "graph_panic"),
        (GraphError::Poisoned.into(), 27, "graph_poisoned"),
        (
            ServeError::NonFinitePayload { index: 3 },
            48,
            "non_finite_payload",
        ),
        (ServeError::UnknownModel { model: 7 }, 49, "unknown_model"),
    ];
    let mut seen = std::collections::BTreeSet::new();
    for (e, code, name) in &table {
        assert_eq!(e.code(), *code, "{e:?} renumbered its stable code");
        assert_eq!(
            ServeError::code_name(*code),
            Some(*name),
            "code {code} lost its PROTOCOL.md name"
        );
        assert_ne!(*code, 0, "0 is reserved for success frames");
        assert!(seen.insert(*code), "code {code} collides");
        // Display renders something, and wrapped variants chain their
        // cause while the wire-policy leaf does not.
        assert!(!e.to_string().is_empty(), "{e:?}");
        match e {
            ServeError::NonFinitePayload { .. } | ServeError::UnknownModel { .. } => {
                assert!(e.source().is_none(), "{e:?} is a leaf")
            }
            _ => assert!(e.source().is_some(), "{e:?} must chain its cause"),
        }
    }
    // Engine-wrapped graph refusals surface the *graph* code on the
    // wire — the root cause, not a generic engine bucket.
    assert_eq!(
        ServeError::from(AdmissionError::Engine(GraphError::EmptyBatch)).code(),
        ServeError::from(GraphError::EmptyBatch).code(),
    );
    // Exhaustive: a new variant without a table row must fail loudly.
    assert_eq!(table.len(), 19);
}

// ---------------------------------------------------------------------------
// Replica pool: killed replicas, re-sharding, work stealing
// ---------------------------------------------------------------------------

mod pool {
    use super::*;
    use swcnn::coordinator::PoolBuilder;
    use swcnn::executor::CompiledModel;

    /// One shared compiled model for the whole module: every pool below
    /// clones the same `Arc` — which is exactly the shared-filter-bank
    /// contract the pool exists for, and keeps 100-seed loops cheap.
    fn tiny_model() -> Arc<CompiledModel> {
        let g = GraphBuilder::new("tiny", (2, 8, 8))
            .pad(1)
            .conv2d("c0", 4, 3)
            .relu()
            .maxpool2()
            .flatten()
            .fc("head", OUT_ELEMS)
            .build()
            .expect("tiny graph builds");
        Arc::new(
            CompiledModel::uniform(g, &mut Synthetic::new(3), ExecPolicy::dense(2))
                .expect("tiny compiles"),
        )
    }

    /// Acceptance gate: 100 seeds of a killed replica under load.  The
    /// injected kill fires before the engine touches the batch, so a
    /// surviving replica re-serves everything the dead one held —
    /// every admitted request completes exactly once, bit-identical to
    /// a direct forward, and nothing hangs.
    #[test]
    fn killed_replica_every_request_completes_exactly_once_100_seeds() {
        quiet_injected_panics();
        let model = tiny_model();
        let x = image(77);
        let want = {
            let mut s = swcnn::executor::Session::from_model(Arc::clone(&model));
            s.forward(&x).expect("baseline forward")
        };
        for seed in 0..100u64 {
            let pool = PoolBuilder::new(Arc::clone(&model), 2)
                .restart(fast_restart())
                .window(Duration::ZERO)
                .fault_plan(0, FaultPlan::seeded(seed).kill_on_batch(0))
                .start()
                .expect("pool starts");
            let replies: Vec<_> = (0..6)
                .map(|_| pool.infer_async(x.clone()).expect("admitted"))
                .collect();
            for (i, rx) in replies.into_iter().enumerate() {
                let result = rx
                    .recv_timeout(Duration::from_secs(30))
                    .expect("admitted request must complete, never hang");
                match result {
                    Ok(y) => assert_eq!(
                        y, want,
                        "seed {seed} request {i}: recovery must be bit-identical"
                    ),
                    Err(e) => panic!(
                        "seed {seed} request {i}: a surviving replica must re-serve \
                         the dead one's work, got {e:?}"
                    ),
                }
                assert!(
                    rx.try_recv().is_err(),
                    "seed {seed} request {i}: completed twice"
                );
            }
            // The death was journaled and only replica 0 is gone.
            assert_eq!(pool.dead_replicas(), vec![0], "seed {seed}");
            assert!(
                pool.fault_events()
                    .iter()
                    .any(|e| matches!(e, FaultEvent::WorkerDied)),
                "seed {seed}: kill not journaled"
            );
        }
    }

    /// With no survivor, orphaned requests complete with a typed
    /// `WorkerFault` — never silence, never a hang — and the dead pool
    /// refuses new admissions synchronously.
    #[test]
    fn pool_with_no_survivor_fails_typed_never_hangs() {
        quiet_injected_panics();
        let pool = PoolBuilder::new(tiny_model(), 1)
            .restart(fast_restart())
            .window(Duration::from_millis(5))
            .fault_plan(0, FaultPlan::seeded(8).kill_on_batch(0))
            .start()
            .expect("pool starts");
        let replies: Vec<_> = (0..3)
            .map(|i| pool.infer_async(image(1 + i)).expect("admitted"))
            .collect();
        for rx in replies {
            match rx.recv_timeout(Duration::from_secs(10)).expect("completes") {
                Err(AdmissionError::WorkerFault { msg }) => {
                    assert!(msg.contains("replica"), "{msg}")
                }
                other => panic!("no-survivor completion must be WorkerFault, got {other:?}"),
            }
        }
        assert_eq!(pool.dead_replicas(), vec![0]);
        assert!(pool
            .fault_events()
            .iter()
            .any(|e| matches!(e, FaultEvent::WorkerDied)));
        match pool.infer(image(9)) {
            Err(AdmissionError::WorkerFault { .. }) => {}
            other => panic!("dead pool must refuse typed, got {other:?}"),
        }
    }

    /// Shard fairness and work stealing under a pipelined burst: the
    /// admission round-robin lands traffic on every shard, and when one
    /// replica stalls mid-batch the healthy one steals the matured
    /// queue behind it instead of idling.
    #[test]
    fn healthy_replica_steals_matured_work_from_a_stalled_shard() {
        quiet_injected_panics();
        let pool = PoolBuilder::new(tiny_model(), 2)
            .restart(fast_restart())
            .window(Duration::from_micros(500))
            .max_batch(2)
            .fault_plan(
                0,
                FaultPlan::seeded(11).latency_every_batch(Duration::from_millis(250)),
            )
            .start()
            .expect("pool starts");
        let x = image(5);
        let replies: Vec<_> = (0..12)
            .map(|_| pool.infer_async(x.clone()).expect("admitted"))
            .collect();
        for rx in replies {
            rx.recv_timeout(Duration::from_secs(30))
                .expect("completes")
                .expect("both shards serve");
        }
        let m = pool.metrics.lock().unwrap();
        assert_eq!(m.requests, 12);
        // Fairness: strict round-robin admission fed both shards.
        assert!(
            m.replica_dispatch().iter().all(|&d| d > 0),
            "every shard must see traffic: {:?}",
            m.replica_dispatch()
        );
        // Stealing: the healthy replica (1) took matured work off the
        // stalled shard's queue — the straggler never strands a burst.
        assert!(
            m.replica_steals()[1] > 0,
            "healthy replica must steal from the stall: {:?}",
            m.replica_steals()
        );
    }
}

// ---------------------------------------------------------------------------
// Stress smoke (CI runs this with --ignored)
// ---------------------------------------------------------------------------

/// 100 seeds of random panic schedules; every successful completion
/// must be bit-identical to the fault-free baseline and every failure
/// typed.  On any violation the fault journal lands in
/// `FAULT_stress.log` (the CI artifact).
#[test]
#[ignore = "stress smoke — run explicitly (CI does, with --ignored)"]
fn stress_supervisor_restart_100x() {
    quiet_injected_panics();
    let x = image(77);
    let baseline = tiny_cfg()
        .start()
        .expect("baseline")
        .infer(x.clone())
        .expect("fault-free serve");

    for seed in 0..100u64 {
        let plan = FaultPlan::seeded(seed).with_random_panics(12, 0.3);
        let server = tiny_cfg().fault_plan(plan).start().expect("start");
        for i in 0..12 {
            match server.infer(x.clone()) {
                Ok(y) => {
                    if y != baseline {
                        let log = render_log(&server.fault_events());
                        std::fs::write("FAULT_stress.log", &log).ok();
                        panic!("seed {seed} batch {i}: post-recovery output diverged\n{log}");
                    }
                }
                Err(AdmissionError::WorkerFault { .. }) => {}
                Err(e) => {
                    let log = render_log(&server.fault_events());
                    std::fs::write("FAULT_stress.log", &log).ok();
                    panic!("seed {seed} batch {i}: untyped failure {e:?}\n{log}");
                }
            }
        }
        // Restarts happened and were journaled whenever the seed
        // scheduled at least one panic.
        let faults = plan_panics(seed);
        if faults > 0 {
            assert!(
                server
                    .fault_events()
                    .iter()
                    .any(|e| matches!(e, FaultEvent::Restarted { .. })),
                "seed {seed}: {faults} scheduled panics but no restart journaled"
            );
        }
    }
}

fn plan_panics(seed: u64) -> usize {
    FaultPlan::seeded(seed)
        .with_random_panics(12, 0.3)
        .panic_batches()
        .count()
}
