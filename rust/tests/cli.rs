//! CLI integration tests: run the built `swcnn` binary end-to-end.

use std::process::Command;

fn swcnn(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_swcnn"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn swcnn");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn cli_report_prints_tables() {
    let (ok, text) = swcnn(&["report"]);
    assert!(ok, "{text}");
    assert!(text.contains("Table 1"));
    assert!(text.contains("12845056"));
    assert!(text.contains("Table 3"));
    assert!(text.contains("512 (arith) + 256 (wino)"));
    assert!(text.contains("Fig. 6"));
}

#[test]
fn cli_simulate_dense_and_sparse() {
    let (ok, text) = swcnn(&["simulate", "--net", "vgg16"]);
    assert!(ok, "{text}");
    assert!(text.contains("conv5_3"));
    assert!(text.contains("Gops/s"));

    let (ok, sparse) = swcnn(&["simulate", "--net", "vgg16", "--sparsity", "0.9"]);
    assert!(ok, "{sparse}");
    // Sparse occupancy must show up below 1.
    assert!(sparse.contains("0.2") || sparse.contains("0.1"), "{sparse}");
}

#[test]
fn cli_sweep() {
    let (ok, text) = swcnn(&["sweep", "--net", "vgg_tiny", "--ms", "2", "--sparsities", "0.9"]);
    assert!(ok, "{text}");
    assert!(text.contains("dense"));
    assert!(text.contains("90%"));
}

#[test]
fn cli_rejects_unknown() {
    let (ok, text) = swcnn(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"));
    let (ok2, text2) = swcnn(&["simulate", "--net", "alexnet"]);
    assert!(!ok2);
    assert!(text2.contains("unknown net"));
}

#[test]
fn cli_help() {
    let (ok, text) = swcnn(&["help"]);
    assert!(ok);
    assert!(text.contains("usage"));
}
