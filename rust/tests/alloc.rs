//! Zero-allocation guards for the fused serving paths (`alloc-count`).
//!
//! `swcnn-lint`'s `hot-no-alloc` rule bans allocation *idioms* inside
//! `// lint: hot` fns, but a static scan cannot see allocation reached
//! through calls.  These tests close the gap dynamically: with the
//! `alloc-count` feature the crate installs a counting global allocator
//! (`util::alloc_count`), and after one warm-up call — which sizes the
//! plan scratch and the session workspace — the dense batch loop, the
//! sparse batch loop, and `Session::forward_batch_into` must perform
//! **zero** heap allocations on the calling thread.
//!
//! Everything runs single-worker (`with_threads(1)` / `with_workers(1)`):
//! multi-worker plans spawn scoped threads, and spawning allocates on the
//! caller — that is a known, accepted cost of the threaded mode, not a
//! steady-state leak (see `util::alloc_count`'s module docs).
//!
//! Run with: `cargo test --features alloc-count --test alloc`
#![cfg(feature = "alloc-count")]

use swcnn::executor::{ExecPolicy, Session};
use swcnn::nn::graph::Synthetic;
use swcnn::nn::vgg_tiny;
use swcnn::tensor::Tensor;
use swcnn::util::alloc_count::{assert_no_alloc, count_allocations};
use swcnn::util::Rng;
use swcnn::winograd::WinogradPlan;

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, rng.gaussian_vec(n))
}

#[test]
fn dense_batch_loop_is_alloc_free_after_warmup() {
    let mut rng = Rng::new(801);
    let w = rand_tensor(&mut rng, &[8, 6, 3, 3]);
    let x = rng.gaussian_vec(2 * 6 * 10 * 12);
    let mut plan = WinogradPlan::new(2, 3).with_threads(1);
    let bank = plan.transform_filters(&w);
    let mut out = vec![0.0f32; 2 * 8 * 8 * 10];
    // Warm-up: sizes the plan's tile/V/Y scratch for these dims.
    plan.conv2d_with_filters_batch_into(2, &x, 10, 12, &bank, &mut out);
    let warm = out.clone();
    out.fill(0.0);
    assert_no_alloc("dense fused batch loop", || {
        plan.conv2d_with_filters_batch_into(2, &x, 10, 12, &bank, &mut out);
    });
    assert_eq!(out, warm, "steady-state call must also be bit-identical");
}

#[test]
fn sparse_batch_loop_is_alloc_free_after_warmup() {
    let mut rng = Rng::new(802);
    let w = rand_tensor(&mut rng, &[8, 6, 3, 3]);
    let x = rng.gaussian_vec(2 * 6 * 10 * 12);
    let mut plan = WinogradPlan::new(2, 3).with_threads(1);
    let bank = plan.transform_filters_sparse(&w, 0.6);
    let mut out = vec![0.0f32; 2 * 8 * 8 * 10];
    // Warm-up: sizes the plan's V/V^T/MM/Y scratch for these dims.
    plan.conv2d_sparse_with_filters_batch_into(2, &x, 10, 12, &bank, &mut out);
    let warm = out.clone();
    out.fill(0.0);
    assert_no_alloc("sparse fused batch loop", || {
        plan.conv2d_sparse_with_filters_batch_into(2, &x, 10, 12, &bank, &mut out);
    });
    assert_eq!(out, warm, "steady-state call must also be bit-identical");
}

#[test]
fn session_forward_batch_into_is_alloc_free_after_warmup() {
    for policy in [
        ExecPolicy::dense(2).with_workers(1),
        ExecPolicy::sparse(2, 0.7).with_workers(1),
    ] {
        let mut sess = Session::uniform(vgg_tiny(), &mut Synthetic::new(5), policy)
            .unwrap()
            .with_max_batch(2);
        let mut rng = Rng::new(803);
        let images: Vec<Vec<f32>> = (0..2).map(|_| rng.gaussian_vec(3 * 32 * 32)).collect();
        let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 2 * sess.output_elements()];
        sess.forward_batch_into(&refs, &mut out).unwrap();
        let warm = out.clone();
        out.fill(0.0);
        assert_no_alloc("Session::forward_batch_into steady state", || {
            sess.forward_batch_into(&refs, &mut out).unwrap();
        });
        assert_eq!(out, warm, "steady-state call must also be bit-identical");
        assert_eq!(
            out[..sess.output_elements()],
            sess.forward(&images[0]).unwrap()[..],
            "the into path matches the allocating path"
        );
    }
}

#[test]
fn session_forward_batch_allocates_only_its_outputs() {
    let mut sess = Session::uniform(
        vgg_tiny(),
        &mut Synthetic::new(5),
        ExecPolicy::sparse(2, 0.7).with_workers(1),
    )
    .unwrap()
    .with_max_batch(2);
    let mut rng = Rng::new(804);
    let images: Vec<Vec<f32>> = (0..2).map(|_| rng.gaussian_vec(3 * 32 * 32)).collect();
    let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
    sess.forward_batch(&refs).unwrap();
    let (outs, delta) = count_allocations(|| sess.forward_batch(&refs).unwrap());
    assert_eq!(outs.len(), 2);
    // The engine itself is alloc-free; the only heap traffic is the
    // returned containers (one outer Vec + one Vec per image).
    assert!(
        delta.allocs <= 3,
        "forward_batch may only allocate its return value: {delta:?}"
    );
}
