//! Network serving front-end: real-TCP round trips, the in-band metrics
//! endpoint, protocol-level error codes, and socketless dispatch tests.
//!
//! Layered per the wire/dispatch/listener split:
//!
//! - the codec's own property and malformed-input tests live with the
//!   codec (`src/coordinator/net/wire.rs`) — no socket there;
//! - this file proves the **dispatch** mapping (frames onto the
//!   admission path, `ServeError` codes onto wire frames) with an
//!   in-memory server and no listener, then the **listener** end to end
//!   over 127.0.0.1 — bit-identical logits, batching under pipelining,
//!   typed refusals that never kill the connection, and drain-on-shutdown.
//!
//! Deterministic fault schedules (queue-full, deadline, panic codes)
//! need the `fault-injection` feature; those tests are gated
//! individually and CI's `net-serving` job runs them.

use std::time::{Duration, Instant};

use swcnn::coordinator::net::dispatch::{self, Dispatched};
use swcnn::coordinator::net::{wire, NetClient, NetError, NetServer};
use swcnn::coordinator::{InferenceServer, ServeBuilder, ServeError};
use swcnn::executor::{ExecPolicy, Session};
use swcnn::nn::graph::{GraphBuilder, GraphError, Synthetic};
use swcnn::nn::vgg_tiny;
use swcnn::util::json::Json;
use swcnn::util::Rng;

#[cfg(feature = "fault-injection")]
use swcnn::coordinator::{AdmissionPolicy, FaultPlan};

const IN_ELEMS: usize = 2 * 8 * 8;
const OUT_ELEMS: usize = 3;

/// A graph small enough that every test stays in the milliseconds.
fn tiny_session() -> Session {
    let g = GraphBuilder::new("tiny", (2, 8, 8))
        .pad(1)
        .conv2d("c0", 4, 3)
        .relu()
        .maxpool2()
        .flatten()
        .fc("head", OUT_ELEMS)
        .build()
        .expect("tiny graph builds");
    Session::uniform(g, &mut Synthetic::new(3), ExecPolicy::dense(2)).expect("tiny compiles")
}

fn tiny_server() -> InferenceServer {
    ServeBuilder::new(tiny_session())
        .max_batch(4)
        .start()
        .expect("start")
}

fn image(seed: u64) -> Vec<f32> {
    Rng::new(seed).gaussian_vec(IN_ELEMS)
}

// ---------------------------------------------------------------------------
// Listener: real TCP, bit-identical serving
// ---------------------------------------------------------------------------

/// Acceptance gate: a real TCP client round-trips an inference through
/// the batcher **bit-identically** to `Session::forward` on the paper's
/// vgg_tiny network.
#[test]
fn tcp_round_trip_bit_identical_to_session_forward() {
    let policy = ExecPolicy::sparse(2, 0.7);
    let mut direct =
        Session::uniform(vgg_tiny(), &mut Synthetic::new(7), policy).expect("session");
    let mut rng = Rng::new(91);
    let image = rng.gaussian_vec(direct.input_elements());
    let want = direct.forward(&image).expect("direct forward");

    let served =
        Session::uniform(vgg_tiny(), &mut Synthetic::new(7), policy).expect("session");
    let server = ServeBuilder::new(served).start().expect("start");
    let net = NetServer::bind("127.0.0.1:0", server).expect("bind");
    let mut client = NetClient::connect(net.local_addr()).expect("connect");
    let got = client.infer(&image).expect("served over TCP");
    assert_eq!(got, want, "network serving must be bit-identical");
}

/// Pipelining N requests on one connection keeps responses in request
/// order, each bit-identical to the direct session — and the requests
/// actually share fused batches (the whole point of the front-end).
#[test]
fn pipelined_requests_stay_in_order_and_share_batches() {
    let mut direct = tiny_session();
    let server = ServeBuilder::new(tiny_session())
        .max_batch(4)
        .window(Duration::from_millis(20))
        .start()
        .expect("start");
    let net = NetServer::bind("127.0.0.1:0", server).expect("bind");
    let mut client = NetClient::connect(net.local_addr()).expect("connect");

    let images: Vec<Vec<f32>> = (0..8).map(|i| image(100 + i)).collect();
    let ids: Vec<u64> = images
        .iter()
        .map(|im| client.send_infer(im, 0).expect("send"))
        .collect();
    for (im, id) in images.iter().zip(&ids) {
        match client.recv().expect("response") {
            wire::Response::Logits { id: got, values } => {
                assert_eq!(got, *id, "responses arrive in request order");
                assert_eq!(values, direct.forward(im).expect("direct"));
            }
            other => panic!("want logits for {id}, got {other:?}"),
        }
    }
    let m = net.server().metrics.lock().unwrap();
    assert_eq!(m.requests, 8);
    assert!(
        m.mean_batch() > 1.0,
        "pipelined traffic must form fused batches, mean {}",
        m.mean_batch()
    );
}

#[test]
fn metrics_endpoint_streams_summary_json_over_tcp() {
    let net = NetServer::bind("127.0.0.1:0", tiny_server()).expect("bind");
    let mut client = NetClient::connect(net.local_addr()).expect("connect");
    for i in 0..3 {
        client.infer(&image(i)).expect("served");
    }
    let doc = client.metrics_json().expect("metrics over TCP");
    let parsed = Json::parse(&doc).expect("endpoint serves valid JSON");
    assert_eq!(
        parsed.req("requests").unwrap().as_f64(),
        Some(3.0),
        "{doc}"
    );
    for key in [
        "batches",
        "mean_batch",
        "p50",
        "p99",
        "rejected_full",
        "ejected_deadline",
        "worker_faults",
        "queue_depth_peak",
        "simd",
        "vwidths",
        "batch_histogram",
    ] {
        assert!(parsed.get(key).is_some(), "metrics JSON missing {key}: {doc}");
    }
    // The in-band endpoint and the in-process accessor serve the same
    // schema (counters may move between the two snapshots).
    let local = Json::parse(&net.metrics_json()).expect("accessor JSON");
    assert!(local.get("requests").is_some());
}

/// A typed per-request refusal must not kill the connection: the same
/// socket keeps serving afterwards.
#[test]
fn typed_refusals_keep_the_connection_alive() {
    let net = NetServer::bind("127.0.0.1:0", tiny_server()).expect("bind");
    let mut client = NetClient::connect(net.local_addr()).expect("connect");

    // Wrong input size -> the engine's Input code.
    let err = client.infer(&[0.0; 7]).expect_err("wrong size refused");
    match &err {
        NetError::Remote { code, msg } => {
            assert_eq!(
                *code,
                ServeError::from(GraphError::Input {
                    index: 0,
                    expected: IN_ELEMS,
                    got: 7,
                })
                .code()
            );
            assert!(msg.contains("expected"), "{msg}");
        }
        other => panic!("want Remote, got {other:?}"),
    }

    // NaN payload -> the wire policy code, still per-request.
    let mut bad = image(5);
    bad[3] = f32::NAN;
    match client.infer(&bad) {
        Err(NetError::Remote { code, msg }) => {
            assert_eq!(code, ServeError::NonFinitePayload { index: 3 }.code());
            assert!(msg.contains("non-finite"), "{msg}");
        }
        other => panic!("want Remote(non_finite), got {other:?}"),
    }

    // Same connection, next request serves fine.
    let y = client.infer(&image(6)).expect("connection survived");
    assert_eq!(y.len(), OUT_ELEMS);
}

/// Shutdown drains: a request admitted before shutdown still flushes
/// its logits to the socket (PR 6 drain semantics through the listener).
#[test]
fn shutdown_drains_admitted_requests_to_the_socket() {
    let net = NetServer::bind("127.0.0.1:0", tiny_server()).expect("bind");
    let mut client = NetClient::connect(net.local_addr()).expect("connect");
    let id = client.send_infer(&image(8), 0).expect("send");
    // Wait until the listener has actually admitted the request (the
    // queue-depth high-water mark moves at admission), then drain.
    let t0 = Instant::now();
    loop {
        let peak = net.server().metrics.lock().unwrap().queue_depth_peak;
        if peak >= 1 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "listener never admitted the request"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    net.shutdown();
    match client.recv().expect("drained completion reaches the socket") {
        wire::Response::Logits { id: got, values } => {
            assert_eq!(got, id);
            assert_eq!(values.len(), OUT_ELEMS);
        }
        other => panic!("drain must serve the admitted request, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Multi-model routing: several models behind one listener
// ---------------------------------------------------------------------------

const WIDE_OUT: usize = 5;

/// Same input shape as [`tiny_session`], different head width and
/// weights — so a misrouted request is observable, not coincidentally
/// correct.
fn wide_session() -> Session {
    let g = GraphBuilder::new("tiny-wide", (2, 8, 8))
        .pad(1)
        .conv2d("c0", 4, 3)
        .relu()
        .maxpool2()
        .flatten()
        .fc("head", WIDE_OUT)
        .build()
        .expect("wide graph builds");
    Session::uniform(g, &mut Synthetic::new(9), ExecPolicy::dense(2)).expect("wide compiles")
}

/// Two compiled models serve behind one listener, each request routed
/// by the model id in header byte 7, each answer bit-identical to its
/// own direct session — and an unmapped id fails typed (code 49)
/// without killing the connection.
#[test]
fn one_listener_routes_multiple_models_by_id() {
    let mut direct_tiny = tiny_session();
    let mut direct_wide = wide_session();
    let tiny = ServeBuilder::new(tiny_session())
        .model(3)
        .start()
        .expect("tiny starts");
    let wide = ServeBuilder::new(wide_session())
        .model(7)
        .start()
        .expect("wide starts");
    let net = NetServer::bind_models("127.0.0.1:0", vec![wide, tiny]).expect("bind");
    assert_eq!(net.models(), vec![3, 7], "table sorts by model id");
    let mut client = NetClient::connect(net.local_addr()).expect("connect");

    // A fresh client addresses model 0 — not served here.  The refusal
    // is a typed per-request frame, and the connection stays up.
    match client.infer(&image(40)) {
        Err(NetError::Remote { code, msg }) => {
            assert_eq!(code, ServeError::UnknownModel { model: 0 }.code());
            assert!(msg.contains("model"), "{msg}");
        }
        other => panic!("want Remote(unknown_model), got {other:?}"),
    }

    // Same socket, interleaved across both models, bit-identical each.
    let x = image(41);
    client.set_model(3);
    assert_eq!(
        client.infer(&x).expect("model 3 serves"),
        direct_tiny.forward(&x).expect("direct tiny")
    );
    client.set_model(7);
    let y = client.infer(&x).expect("model 7 serves");
    assert_eq!(y.len(), WIDE_OUT);
    assert_eq!(y, direct_wide.forward(&x).expect("direct wide"));
    client.set_model(3);
    assert_eq!(
        client.infer(&x).expect("model 3 again"),
        direct_tiny.forward(&x).expect("direct tiny")
    );

    // The in-band metrics endpoint is per model: each server counted
    // exactly the requests routed to it.
    let doc = client.metrics_json().expect("model 3 metrics");
    let parsed = Json::parse(&doc).expect("valid JSON");
    assert_eq!(parsed.req("requests").unwrap().as_f64(), Some(2.0), "{doc}");
    client.set_model(7);
    let doc = client.metrics_json().expect("model 7 metrics");
    let parsed = Json::parse(&doc).expect("valid JSON");
    assert_eq!(parsed.req("requests").unwrap().as_f64(), Some(1.0), "{doc}");
    assert_eq!(net.model_server(3).unwrap().output_elements(), OUT_ELEMS);
    assert_eq!(net.model_server(7).unwrap().output_elements(), WIDE_OUT);
    assert!(net.model_server(0).is_none());
}

/// `bind` stays the single-model sugar: whatever the server's id, a
/// default client (model 0) only reaches it when the ids agree.
#[test]
fn single_model_bind_keeps_default_clients_working() {
    let net = NetServer::bind("127.0.0.1:0", tiny_server()).expect("bind");
    assert_eq!(net.models(), vec![0]);
    let mut client = NetClient::connect(net.local_addr()).expect("connect");
    assert_eq!(client.model(), 0, "fresh clients address the default");
    assert_eq!(client.infer(&image(50)).expect("serves").len(), OUT_ELEMS);
}

#[test]
fn duplicate_model_ids_refuse_the_bind() {
    let a = ServeBuilder::new(tiny_session()).model(2).start().expect("a");
    let b = ServeBuilder::new(tiny_session()).model(2).start().expect("b");
    let err = NetServer::bind_models("127.0.0.1:0", vec![a, b]).expect_err("refused");
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
    assert!(err.to_string().contains("model id 2"), "{err}");
    let err = NetServer::bind_models("127.0.0.1:0", Vec::new()).expect_err("empty refused");
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
}

// ---------------------------------------------------------------------------
// Dispatch: socketless mapping of frames onto the admission path
// ---------------------------------------------------------------------------

#[test]
fn dispatch_needs_no_socket_for_metrics_and_refusals() {
    let server = tiny_server();
    // Metrics resolve synchronously with the summary JSON.
    match dispatch::dispatch(&server, wire::Request::Metrics { id: 4, model: 0 }) {
        Dispatched::Now(wire::Response::MetricsJson { id: 4, json }) => {
            assert!(Json::parse(&json).is_ok(), "{json}");
        }
        other => panic!("want MetricsJson, got {other:?}"),
    }
    // A shut-down server refuses with the stable ShuttingDown code.
    server.shutdown(false);
    match dispatch::dispatch(
        &server,
        wire::Request::Infer {
            id: 5,
            model: 0,
            deadline_ms: 0,
            image: image(1),
        },
    ) {
        Dispatched::Now(wire::Response::Error { id: 5, code, .. }) => {
            assert_eq!(code, 2, "shutting_down");
        }
        other => panic!("want Error(shutting_down), got {other:?}"),
    }
}

#[cfg(feature = "fault-injection")]
mod faulted_dispatch {
    use super::*;

    /// Block until the worker has pulled everything queued into a batch
    /// dispatch (same idiom as tests/robustness.rs).
    fn wait_queue_drained(server: &InferenceServer) {
        let t0 = Instant::now();
        while server.queue_depth() > 0 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "worker never picked up the queued batch"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    fn infer_frame(id: u64, deadline_ms: u32, seed: u64) -> wire::Request {
        wire::Request::Infer {
            id,
            model: 0,
            deadline_ms,
            image: image(seed),
        }
    }

    fn expect_error_code(d: Dispatched, want: u16) {
        let resp = match d {
            Dispatched::Now(resp) => resp,
            Dispatched::Pending { id, reply } => dispatch::resolve(id, &reply),
        };
        match resp {
            wire::Response::Error { code, .. } => assert_eq!(code, want),
            other => panic!("want error code {want}, got {other:?}"),
        }
    }

    #[test]
    fn queue_full_surfaces_code_1() {
        let server = ServeBuilder::new(tiny_session())
            .queue(1, AdmissionPolicy::RejectNew)
            .window(Duration::ZERO)
            .fault_plan(FaultPlan::seeded(3).latency_every_batch(Duration::from_millis(250)))
            .start()
            .expect("start");
        let stall = server.infer_async(image(1)).expect("admitted");
        wait_queue_drained(&server); // worker now inside the stalled batch
        let queued = server.infer_async(image(2)).expect("fills the queue");
        expect_error_code(dispatch::dispatch(&server, infer_frame(7, 0, 3)), 1);
        for rx in [stall, queued] {
            rx.recv_timeout(Duration::from_secs(10))
                .expect("completes")
                .expect("admitted work still serves");
        }
    }

    #[test]
    fn expired_deadline_surfaces_code_3() {
        let server = ServeBuilder::new(tiny_session())
            .window(Duration::ZERO)
            .fault_plan(FaultPlan::seeded(2).latency_on_batch(0, Duration::from_millis(300)))
            .start()
            .expect("start");
        let stall = server.infer_async(image(1)).expect("admitted");
        wait_queue_drained(&server);
        // A 30ms wire deadline expires while batch 0 crawls.
        expect_error_code(dispatch::dispatch(&server, infer_frame(8, 30, 4)), 3);
        stall
            .recv_timeout(Duration::from_secs(10))
            .expect("completes")
            .expect("stalled batch still serves");
    }

    #[test]
    fn worker_panic_surfaces_code_5() {
        // Injected panic payloads are expected; silence their hook spam.
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .unwrap_or("");
            if !msg.contains("fault-injection") {
                prev(info);
            }
        }));
        let server = ServeBuilder::new(tiny_session())
            .fault_plan(FaultPlan::seeded(1).panic_on_batch(0))
            .start()
            .expect("start");
        expect_error_code(dispatch::dispatch(&server, infer_frame(9, 0, 5)), 5);
    }
}

// ---------------------------------------------------------------------------
// Protocol-level coverage of the full ServeError code table
// ---------------------------------------------------------------------------

/// Every `ServeError` code crosses the wire verbatim: construct each
/// variant, wrap it as the dispatch layer would, encode, decode, and
/// check the code survives and stays collision-free.
#[test]
fn every_serve_error_code_crosses_the_wire_verbatim() {
    use swcnn::coordinator::AdmissionError;
    let errors: Vec<ServeError> = vec![
        AdmissionError::QueueFull { capacity: 1 }.into(),
        AdmissionError::ShuttingDown.into(),
        AdmissionError::DeadlineExpired {
            deadline: Duration::from_millis(1),
            waited: Duration::from_millis(2),
        }
        .into(),
        AdmissionError::CircuitOpen {
            consecutive_faults: 1,
        }
        .into(),
        AdmissionError::WorkerFault { msg: "x".into() }.into(),
        GraphError::Shape {
            node: 0,
            msg: "x".into(),
        }
        .into(),
        GraphError::Policy("x".into()).into(),
        GraphError::PolicyCount {
            expected: 1,
            got: 2,
        }
        .into(),
        GraphError::Input {
            index: 0,
            expected: 1,
            got: 2,
        }
        .into(),
        GraphError::Output {
            expected: 1,
            got: 2,
        }
        .into(),
        GraphError::EmptyBatch.into(),
        GraphError::BatchTooLarge { got: 9, max: 4 }.into(),
        GraphError::Weights("x".into()).into(),
        GraphError::Io("x".into()).into(),
        GraphError::Config("x".into()).into(),
        GraphError::Panic("x".into()).into(),
        GraphError::Poisoned.into(),
        ServeError::NonFinitePayload { index: 3 },
        ServeError::UnknownModel { model: 9 },
    ];
    assert_eq!(errors.len(), 19, "table must cover every variant");
    let mut seen = std::collections::BTreeSet::new();
    for (i, err) in errors.iter().enumerate() {
        let resp = dispatch::error_response(i as u64, err);
        let mut bytes = Vec::new();
        wire::encode_response(&resp, &mut bytes);
        match wire::decode_response_exact(&bytes).expect("error frame decodes") {
            wire::Response::Error { id, code, msg } => {
                assert_eq!(id, i as u64);
                assert_eq!(code, err.code(), "{err:?} code mangled in transit");
                assert_ne!(code, 0, "0 is reserved for success");
                assert!(
                    ServeError::code_name(code).is_some(),
                    "{err:?} -> unnamed code {code}"
                );
                assert_eq!(msg, err.to_string());
                assert!(seen.insert(code), "{err:?} collides on code {code}");
            }
            other => panic!("{err:?} must encode as an error frame, got {other:?}"),
        }
    }
}
