//! Integration tests across the runtime + coordinator + simulator.
//!
//! PJRT tests need `make artifacts` first; they are skipped (with a
//! loud message) when artifacts/ is absent so `cargo test` stays usable
//! in a fresh checkout.

use swcnn::coordinator::{InferenceServer, ServerConfig};
use swcnn::runtime::{read_f32_bin, Runtime};
use swcnn::tensor::Tensor;
use swcnn::util::Rng;
use swcnn::winograd::direct_conv2d;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn quickstart_matches_direct_conv() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let model = rt.load("quickstart").unwrap();
    let meta = &model.spec.meta;
    let (c, k, h, w) = (
        meta.req("C").unwrap().as_usize().unwrap(),
        meta.req("K").unwrap().as_usize().unwrap(),
        meta.req("H").unwrap().as_usize().unwrap(),
        meta.req("W").unwrap().as_usize().unwrap(),
    );
    let mut rng = Rng::new(17);
    let x = rng.gaussian_vec(c * h * w);
    let y = Tensor::from_vec(&[k, h, w], model.run(&[x.clone()]).unwrap()[0].clone());

    let g_meta = meta.req("g_spatial").unwrap();
    let g = read_f32_bin(
        &dir.join(g_meta.req("file").unwrap().as_str().unwrap()),
        k * c * 9,
    )
    .unwrap();
    let g = Tensor::from_vec(&[k, c, 3, 3], g);
    let mut xp = Tensor::zeros(&[c, h + 2, w + 2]);
    for cc in 0..c {
        for i in 0..h {
            for j in 0..w {
                xp.set3(cc, i + 1, j + 1, x[(cc * h + i) * w + j]);
            }
        }
    }
    let mut want = direct_conv2d(&xp, &g);
    for v in want.data_mut() {
        *v = v.max(0.0);
    }
    let diff = y.max_abs_diff(&want);
    assert!(diff < 1e-3, "pjrt vs direct conv: {diff}");
}

#[test]
fn vgg_tiny_b1_finite_and_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let model = rt.load("vgg_tiny_b1").unwrap();
    let mut rng = Rng::new(23);
    let x = rng.gaussian_vec(3 * 32 * 32);
    let y1 = model.run(&[x.clone()]).unwrap();
    let y2 = model.run(&[x]).unwrap();
    assert_eq!(y1[0].len(), 10);
    assert!(y1[0].iter().all(|v| v.is_finite()));
    assert_eq!(y1[0], y2[0], "execution must be deterministic");
}

#[test]
fn vgg_tiny_batch_matches_single() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let b1 = rt.load("vgg_tiny_b1").unwrap();
    let b4 = rt.load("vgg_tiny_b4").unwrap();
    let mut rng = Rng::new(29);
    let imgs: Vec<Vec<f32>> = (0..4).map(|_| rng.gaussian_vec(3 * 32 * 32)).collect();
    let mut stacked = Vec::new();
    for img in &imgs {
        stacked.extend_from_slice(img);
    }
    let batched = b4.run(&[stacked]).unwrap();
    for (i, img) in imgs.iter().enumerate() {
        let single = b1.run(&[img.clone()]).unwrap();
        let b = &batched[0][i * 10..(i + 1) * 10];
        for (s, bb) in single[0].iter().zip(b) {
            assert!((s - bb).abs() < 1e-4, "img {i}: {s} vs {bb}");
        }
    }
}

#[test]
fn sparse_artifact_runs_and_differs_from_dense() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let dense = rt.load("vgg_tiny_b1").unwrap();
    let sparse = rt.load("vgg_tiny_sparse_b1").unwrap();
    assert!((sparse.spec.meta.req("sparsity").unwrap().as_f64().unwrap() - 0.8).abs() < 1e-9);
    let mut rng = Rng::new(31);
    let x = rng.gaussian_vec(3 * 32 * 32);
    let yd = dense.run(&[x.clone()]).unwrap();
    let ys = sparse.run(&[x]).unwrap();
    assert!(ys[0].iter().all(|v| v.is_finite()));
    // 80% of weight blocks pruned -> logits must differ.
    let diff: f32 = yd[0]
        .iter()
        .zip(&ys[0])
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(diff > 1e-3, "pruning 80% of weights changed nothing?");
}

#[test]
fn m_sweep_artifacts_agree_with_each_other() {
    // The same layer lowered at m = 2/4/6 must compute the same function.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let m2 = rt.load("layer_m2").unwrap();
    let m4 = rt.load("layer_m4").unwrap();
    let m6 = rt.load("layer_m6").unwrap();
    let mut rng = Rng::new(37);
    let x = rng.gaussian_vec(32 * 16 * 16);
    let y2 = m2.run(&[x.clone()]).unwrap();
    let y4 = m4.run(&[x.clone()]).unwrap();
    let y6 = m6.run(&[x]).unwrap();
    let max_diff = |a: &[f32], b: &[f32]| {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max)
    };
    assert!(max_diff(&y2[0], &y4[0]) < 1e-2, "m2 vs m4");
    assert!(max_diff(&y2[0], &y6[0]) < 1e-2, "m2 vs m6");
}

#[test]
fn fc_artifact_matches_cpu() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let fc = rt.load("fc").unwrap();
    let w = read_f32_bin(&dir.join("fc__w.bin"), 512 * 128).unwrap();
    let b = read_f32_bin(&dir.join("fc__b.bin"), 128).unwrap();
    let mut rng = Rng::new(41);
    let x = rng.gaussian_vec(512);
    let y = fc.run(&[x.clone()]).unwrap();
    for j in 0..128 {
        let mut acc = b[j];
        for i in 0..512 {
            acc += x[i] * w[i * 128 + j];
        }
        let want = acc.max(0.0);
        assert!((y[0][j] - want).abs() < 1e-3, "fc[{j}]: {} vs {want}", y[0][j]);
    }
}

#[test]
fn server_end_to_end_with_batching() {
    let Some(dir) = artifacts_dir() else { return };
    let server = InferenceServer::start(ServerConfig::new(dir, "vgg_tiny")).unwrap();
    let mut rng = Rng::new(43);
    let elems = server.input_elements();

    // Fire a burst to exercise the batcher, then check every response.
    let imgs: Vec<Vec<f32>> = (0..10).map(|_| rng.gaussian_vec(elems)).collect();
    let rxs: Vec<_> = imgs
        .iter()
        .map(|img| server.infer_async(img.clone()).expect("admitted"))
        .collect();
    let burst: Vec<Vec<f32>> = rxs
        .into_iter()
        .map(|rx| rx.recv().unwrap().unwrap())
        .collect();
    // Solo reference for each image.
    for (img, got) in imgs.iter().zip(&burst) {
        let solo = server.infer(img.clone()).unwrap();
        for (a, b) in solo.iter().zip(got) {
            assert!((a - b).abs() < 1e-4);
        }
    }
    let m = server.metrics.lock().unwrap();
    assert!(m.requests >= 20);
    assert!(m.batches >= 2);
}

#[test]
fn server_rejects_wrong_input_size() {
    let Some(dir) = artifacts_dir() else { return };
    let server = InferenceServer::start(ServerConfig::new(dir, "vgg_tiny")).unwrap();
    let res = server.infer(vec![0.0; 7]);
    assert!(res.is_err());
}

#[test]
fn runtime_missing_artifact_errors() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    assert!(rt.load("does_not_exist").is_err());
}

#[test]
fn fused_artifact_matches_staged() {
    // The fused megakernel artifact shares quickstart's weights; the two
    // executables must compute the same function.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let staged = rt.load("quickstart").unwrap();
    let Ok(fused) = rt.load("quickstart_fused") else {
        eprintln!("SKIP: quickstart_fused not in manifest (rebuild artifacts)");
        return;
    };
    let mut rng = Rng::new(47);
    let x = rng.gaussian_vec(8 * 16 * 16);
    let ys = staged.run(&[x.clone()]).unwrap();
    let yf = fused.run(&[x]).unwrap();
    let diff = ys[0]
        .iter()
        .zip(&yf[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(diff < 1e-3, "fused vs staged: {diff}");
}

#[test]
fn vgg16_conv5_layer_executes_at_paper_scale() {
    // The real VGG16 conv5 shape (512x512 @ 14x14) through PJRT — the
    // paper's heaviest per-layer matmul family.  Before the §Perf no-grid
    // kernel rewrite this took ~53 s; it must now be interactive.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let model = rt.load("vgg16_conv5").unwrap();
    let mut rng = Rng::new(53);
    let x = rng.gaussian_vec(512 * 14 * 14);
    let t0 = std::time::Instant::now();
    let y = model.run(&[x]).unwrap();
    let dt = t0.elapsed();
    assert_eq!(y[0].len(), 512 * 14 * 14);
    assert!(y[0].iter().all(|v| v.is_finite()));
    assert!(y[0].iter().any(|&v| v > 0.0), "ReLU output all zero");
    assert!(
        dt.as_secs_f64() < 5.0,
        "conv5 execution took {dt:?} — no-grid kernel regression?"
    );
}

// ---------------------------------------------------------------------------
// Native serving path (no artifacts needed): the transform-domain sparse
// pipeline end-to-end — graph -> Session -> batcher.
// ---------------------------------------------------------------------------

#[test]
fn native_server_end_to_end_sparse_pipeline() {
    use swcnn::coordinator::ServeBuilder;
    use swcnn::executor::{ExecPolicy, Session};
    use swcnn::nn::graph::Synthetic;
    use swcnn::nn::vgg_tiny;

    let session = Session::uniform(
        vgg_tiny(),
        &mut Synthetic::new(7),
        ExecPolicy::sparse(2, 0.8),
    )
    .unwrap();
    let server = ServeBuilder::new(session).start().unwrap();
    let mut rng = Rng::new(44);
    let elems = server.input_elements();
    assert_eq!(elems, 3 * 32 * 32);

    // Burst to exercise batching, then solo re-runs must be identical
    // (the native engine is deterministic regardless of batch packing).
    let imgs: Vec<Vec<f32>> = (0..6).map(|_| rng.gaussian_vec(elems)).collect();
    let rxs: Vec<_> = imgs
        .iter()
        .map(|img| server.infer_async(img.clone()).expect("admitted"))
        .collect();
    let burst: Vec<Vec<f32>> = rxs
        .into_iter()
        .map(|rx| rx.recv().unwrap().unwrap())
        .collect();
    for (img, got) in imgs.iter().zip(&burst) {
        assert_eq!(got.len(), server.output_elements());
        assert!(got.iter().all(|v| v.is_finite()));
        let solo = server.infer(img.clone()).unwrap();
        assert_eq!(&solo, got, "batched vs solo must be bit-identical");
    }
    let m = server.metrics.lock().unwrap();
    assert!(m.requests >= 12);
}
