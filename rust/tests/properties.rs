//! Randomized property tests (seeded xoshiro — the offline crate set has
//! no proptest).  Each property runs many random cases; failures print
//! the seed/case so they reproduce deterministically.

use swcnn::sparse::{prune_blocks, synthetic_sparse_matrix, Bcoo};
use swcnn::systolic::cluster::{BlockMatrix, Cluster};
use swcnn::systolic::{BlockTiming, SystolicArray};
use swcnn::tensor::Tensor;
use swcnn::util::Rng;
use swcnn::winograd;
use swcnn::winograd::WinogradPlan;
use swcnn::zmorton;

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, rng.gaussian_vec(n))
}

#[test]
fn prop_winograd_equals_direct_conv_random_shapes() {
    let mut rng = Rng::new(1001);
    for case in 0..40 {
        let m = [2, 3, 4, 6][rng.next_below(4)];
        let c = 1 + rng.next_below(5);
        let k = 1 + rng.next_below(5);
        let h = 7 + rng.next_below(12);
        let w = 7 + rng.next_below(12);
        let x = rand_tensor(&mut rng, &[c, h, w]);
        let wt = rand_tensor(&mut rng, &[k, c, 3, 3]);
        let direct = winograd::direct_conv2d(&x, &wt);
        let wino = winograd::winograd_conv2d(&x, &wt, m);
        assert!(
            direct.allclose(&wino, 2e-3, 2e-3),
            "case {case}: m={m} C={c} K={k} {h}x{w}, diff {}",
            direct.max_abs_diff(&wino)
        );
    }
}

#[test]
fn prop_plan_conv2d_matches_direct_nonaligned() {
    // The plan engine against the direct-convolution oracle for every
    // supported tile size, on spatial sizes chosen to exercise the
    // zero-padded edge-tile path (outputs not multiples of m).
    let mut rng = Rng::new(1011);
    for &m in &[2usize, 4, 6] {
        let mut plan = WinogradPlan::new(m, 3);
        for case in 0..10 {
            let c = 1 + rng.next_below(4);
            let k = 1 + rng.next_below(4);
            // h, w in [7, 19): rarely tile-aligned for any m.
            let h = 7 + rng.next_below(12);
            let w = 7 + rng.next_below(12);
            let x = rand_tensor(&mut rng, &[c, h, w]);
            let wt = rand_tensor(&mut rng, &[k, c, 3, 3]);
            let got = plan.conv2d(&x, &wt);
            let want = winograd::direct_conv2d(&x, &wt);
            assert!(
                got.allclose(&want, 2e-3, 2e-3),
                "case {case}: F({m},3) C={c} K={k} {h}x{w}, diff {}",
                got.max_abs_diff(&want)
            );
        }
    }
}

#[test]
fn prop_plan_threaded_bit_identical_to_single() {
    // Tile sharding must not change the floating-point accumulation
    // order per output element: any worker count is bit-identical.
    let mut rng = Rng::new(1012);
    for case in 0..6 {
        let m = [2usize, 4, 6][rng.next_below(3)];
        let c = 1 + rng.next_below(6);
        let k = 1 + rng.next_below(9);
        let h = 8 + rng.next_below(17);
        let w = 8 + rng.next_below(17);
        let x = rand_tensor(&mut rng, &[c, h, w]);
        let wt = rand_tensor(&mut rng, &[k, c, 3, 3]);
        let mut single = WinogradPlan::new(m, 3).with_threads(1);
        let want = single.conv2d(&x, &wt);
        for threads in [2usize, 5] {
            let mut multi = WinogradPlan::new(m, 3).with_threads(threads);
            let got = multi.conv2d(&x, &wt);
            assert_eq!(
                got, want,
                "case {case}: F({m},3) C={c} K={k} {h}x{w} threads={threads}"
            );
        }
    }
}

#[test]
fn prop_plan_filter_bank_reuse_exact() {
    // transform_filters once + conv2d_with_filters repeatedly must equal
    // the one-shot path exactly (the serving steady state).
    let mut rng = Rng::new(1013);
    let mut plan = WinogradPlan::new(4, 3);
    for case in 0..5 {
        let c = 1 + rng.next_below(5);
        let k = 1 + rng.next_below(5);
        let wt = rand_tensor(&mut rng, &[k, c, 3, 3]);
        let bank = plan.transform_filters(&wt);
        for _ in 0..3 {
            let h = 7 + rng.next_below(10);
            let w = 7 + rng.next_below(10);
            let x = rand_tensor(&mut rng, &[c, h, w]);
            let got = plan.conv2d_with_filters(&x, &bank);
            let want = plan.conv2d(&x, &wt);
            assert_eq!(got, want, "case {case}: bank reuse must be exact");
        }
    }
}

#[test]
fn prop_sparse_plan_zero_sparsity_bit_identical_to_dense() {
    // The fused sparse loop at block sparsity 0.0 must be bit-identical
    // to the dense plan for every tile size, including non-tile-aligned
    // shapes — the per-output accumulation order is the same.
    let mut rng = Rng::new(1014);
    for &m in &[2usize, 4, 6] {
        let mut plan = WinogradPlan::new(m, 3);
        for case in 0..8 {
            let c = 1 + rng.next_below(6);
            let k = 1 + rng.next_below(6);
            let h = 7 + rng.next_below(12);
            let w = 7 + rng.next_below(12);
            let x = rand_tensor(&mut rng, &[c, h, w]);
            let wt = rand_tensor(&mut rng, &[k, c, 3, 3]);
            let sbank = plan.transform_filters_sparse(&wt, 0.0);
            let dbank = plan.transform_filters(&wt);
            let ys = plan.conv2d_sparse_with_filters(&x, &sbank);
            let yd = plan.conv2d_with_filters(&x, &dbank);
            assert_eq!(ys, yd, "case {case}: F({m},3) C={c} K={k} {h}x{w}");
        }
    }
}

#[test]
fn prop_sparse_plan_matches_decompressed_dense_run() {
    // At any sparsity, the sparse loop equals a dense run of the
    // decompressed pruned bank (same values, same summation order).
    let mut rng = Rng::new(1015);
    for case in 0..12 {
        let m = [2usize, 4][rng.next_below(2)];
        let c = 1 + rng.next_below(9);
        let k = 1 + rng.next_below(9);
        let h = 7 + rng.next_below(10);
        let w = 7 + rng.next_below(10);
        let sparsity = rng.next_f64() * 0.9;
        let x = rand_tensor(&mut rng, &[c, h, w]);
        let wt = rand_tensor(&mut rng, &[k, c, 3, 3]);
        let mut plan = WinogradPlan::new(m, 3);
        let sbank = plan.transform_filters_sparse(&wt, sparsity);
        let ys = plan.conv2d_sparse_with_filters(&x, &sbank);
        let yd = plan.conv2d_with_filters(&x, &sbank.to_dense_bank());
        assert_eq!(
            ys, yd,
            "case {case}: F({m},3) C={c} K={k} {h}x{w} p={sparsity:.2}"
        );
    }
}

#[test]
fn prop_sparse_plan_threaded_bit_identical() {
    let mut rng = Rng::new(1016);
    for case in 0..6 {
        let m = [2usize, 4, 6][rng.next_below(3)];
        let c = 1 + rng.next_below(6);
        let k = 1 + rng.next_below(9);
        let h = 8 + rng.next_below(17);
        let w = 8 + rng.next_below(17);
        let sparsity = rng.next_f64() * 0.8;
        let x = rand_tensor(&mut rng, &[c, h, w]);
        let wt = rand_tensor(&mut rng, &[k, c, 3, 3]);
        let mut single = WinogradPlan::new(m, 3).with_threads(1);
        let bank = single.transform_filters_sparse(&wt, sparsity);
        let want = single.conv2d_sparse_with_filters(&x, &bank);
        for threads in [2usize, 5] {
            let mut multi = WinogradPlan::new(m, 3).with_threads(threads);
            let got = multi.conv2d_sparse_with_filters(&x, &bank);
            assert_eq!(
                got, want,
                "case {case}: F({m},3) C={c} K={k} {h}x{w} threads={threads}"
            );
        }
    }
}

#[test]
fn prop_simd_widths_bit_identical_all_backends() {
    // The vector-width knob must be invisible to the numerics: for every
    // (m, width, backend) combination, on non-aligned H/W (edge tiles and
    // remainder lanes always in play), the SIMD path reproduces the
    // scalar path bit for bit — `==`, not `allclose`.
    use swcnn::winograd::VectorWidth;
    let mut rng = Rng::new(1021);
    for &m in &[2usize, 4, 6] {
        for case in 0..4 {
            let c = 1 + rng.next_below(6);
            let k = 1 + rng.next_below(6);
            let h = 7 + rng.next_below(12);
            let w = 7 + rng.next_below(12);
            let sparsity = rng.next_f64() * 0.7;
            let x = rand_tensor(&mut rng, &[c, h, w]);
            let wt = rand_tensor(&mut rng, &[k, c, 3, 3]);
            let mut scalar = WinogradPlan::new(m, 3).with_vector_width(VectorWidth::Scalar);
            let dbank = scalar.transform_filters(&wt);
            let sbank = scalar.transform_filters_sparse(&wt, sparsity);
            let want_d = scalar.conv2d_with_filters(&x, &dbank);
            let want_s = scalar.conv2d_sparse_with_filters(&x, &sbank);
            for vw in VectorWidth::ALL {
                // Transform under the vector path too — the filter
                // transform must also be bit-identical.
                let mut plan = WinogradPlan::new(m, 3).with_vector_width(vw);
                let dbank_w = plan.transform_filters(&wt);
                let sbank_w = plan.transform_filters_sparse(&wt, sparsity);
                let got_d = plan.conv2d_with_filters(&x, &dbank_w);
                let got_s = plan.conv2d_sparse_with_filters(&x, &sbank_w);
                assert_eq!(
                    got_d, want_d,
                    "case {case}: F({m},3) C={c} K={k} {h}x{w} width {vw} dense"
                );
                assert_eq!(
                    got_s, want_s,
                    "case {case}: F({m},3) C={c} K={k} {h}x{w} width {vw} sparse"
                );
            }
        }
    }
}

#[test]
fn prop_simd_threaded_determinism_under_vector_path() {
    // Thread sharding and SIMD dispatch compose: any (threads, width)
    // pair is bit-identical to the single-threaded run at that width —
    // and, by the width property above, to the scalar path.
    use swcnn::winograd::VectorWidth;
    let mut rng = Rng::new(1022);
    for case in 0..4 {
        let m = [2usize, 4, 6][rng.next_below(3)];
        let c = 1 + rng.next_below(6);
        let k = 1 + rng.next_below(8);
        let h = 8 + rng.next_below(17);
        let w = 8 + rng.next_below(17);
        let x = rand_tensor(&mut rng, &[c, h, w]);
        let wt = rand_tensor(&mut rng, &[k, c, 3, 3]);
        for vw in [VectorWidth::W4, VectorWidth::W8, VectorWidth::Auto] {
            let mut single = WinogradPlan::new(m, 3)
                .with_threads(1)
                .with_vector_width(vw);
            let bank = single.transform_filters_sparse(&wt, 0.5);
            let want_dense = single.conv2d(&x, &wt);
            let want_sparse = single.conv2d_sparse_with_filters(&x, &bank);
            for threads in [2usize, 5] {
                let mut multi = WinogradPlan::new(m, 3)
                    .with_threads(threads)
                    .with_vector_width(vw);
                assert_eq!(
                    multi.conv2d(&x, &wt),
                    want_dense,
                    "case {case}: F({m},3) {h}x{w} width {vw} threads={threads} dense"
                );
                assert_eq!(
                    multi.conv2d_sparse_with_filters(&x, &bank),
                    want_sparse,
                    "case {case}: F({m},3) {h}x{w} width {vw} threads={threads} sparse"
                );
            }
        }
    }
}

#[test]
#[ignore = "CI simd-leg smoke: run with `cargo test --release --test properties -- --ignored widest`"]
fn widest_width_smoke_bit_identical_on_vgg_sized_layer() {
    // A vgg_tiny-sized conv on the widest vector hardware this machine
    // offers, checked bit for bit against the scalar path on every tile
    // size and both backends.
    use swcnn::winograd::{simd, VectorWidth};
    let widest = simd::widest_supported();
    let mut rng = Rng::new(1023);
    let x = rand_tensor(&mut rng, &[32, 17, 17]);
    let wt = rand_tensor(&mut rng, &[32, 32, 3, 3]);
    for &m in &[2usize, 4, 6] {
        let mut scalar = WinogradPlan::new(m, 3).with_vector_width(VectorWidth::Scalar);
        let mut wide = WinogradPlan::new(m, 3).with_vector_width(widest);
        assert_eq!(
            wide.conv2d(&x, &wt),
            scalar.conv2d(&x, &wt),
            "F({m},3) dense at {widest}"
        );
        let bank_s = scalar.transform_filters_sparse(&wt, 0.7);
        let bank_w = wide.transform_filters_sparse(&wt, 0.7);
        assert_eq!(
            wide.conv2d_sparse_with_filters(&x, &bank_w),
            scalar.conv2d_sparse_with_filters(&x, &bank_s),
            "F({m},3) sparse at {widest}"
        );
    }
    println!(
        "widest width exercised: {widest} on {}",
        simd::detected_features()
    );
}

#[test]
fn prop_tuner_eligible_configs_match_reference() {
    // Every configuration the tuner may emit — (m, workers, backend) over
    // the full candidate grid — must produce the same convolution as the
    // seed per-tile oracle within tolerance.  A tuned profile must never
    // be able to change what a layer computes, only how fast.
    use swcnn::executor::{ConvExecutor, ExecPolicy};
    let mut rng = Rng::new(1018);
    let x = rand_tensor(&mut rng, &[8, 11, 13]);
    let wt = rand_tensor(&mut rng, &[8, 8, 3, 3]);
    for &m in &[2usize, 4, 6] {
        let want = winograd::winograd_conv2d_reference(&x, &wt, m);
        for &workers in &[1usize, 2, 5] {
            for &sparse in &[false, true] {
                // Backend selection rides the threshold exactly as
                // TuneProfile::layer_policies emits it; sparsity 0.0
                // keeps the weights unpruned so both backends hold the
                // same values and only the schedule differs.
                let policy = ExecPolicy {
                    sparse_threshold: if sparse { 0.0 } else { 2.0 },
                    ..ExecPolicy::dense(m).with_workers(workers)
                };
                let mut ex = ConvExecutor::prepare(&wt, &policy).expect("prepare");
                assert_eq!(ex.backend_name(), if sparse { "sparse" } else { "dense" });
                let got = ex.conv2d(&x);
                assert!(
                    got.allclose(&want, 2e-3, 2e-3),
                    "F({m},3) workers={workers} sparse={sparse}: diff {}",
                    got.max_abs_diff(&want)
                );
            }
        }
    }
}

#[test]
fn prop_tuner_crossover_bit_identical_at_zero_sparsity() {
    // The dense/sparse crossover the tuner flips must be numerically
    // invisible: at block sparsity 0.0 the two backends are bit-identical
    // for every candidate m and worker count (the accumulation order per
    // output element is the same ascending-channel walk).
    use swcnn::executor::{ConvExecutor, ExecPolicy};
    let mut rng = Rng::new(1019);
    for case in 0..6 {
        let c = 4 * (1 + rng.next_below(2));
        let k = 4 * (1 + rng.next_below(3));
        let h = 7 + rng.next_below(10);
        let w = 7 + rng.next_below(10);
        let x = rand_tensor(&mut rng, &[c, h, w]);
        let wt = rand_tensor(&mut rng, &[k, c, 3, 3]);
        for &m in &[2usize, 4, 6] {
            for &workers in &[1usize, 3] {
                let base = ExecPolicy::dense(m).with_workers(workers);
                let dense = ExecPolicy {
                    sparse_threshold: 2.0,
                    ..base
                };
                let sparse = ExecPolicy {
                    sparse_threshold: 0.0,
                    ..base
                };
                let yd = ConvExecutor::prepare(&wt, &dense).expect("prepare").conv2d(&x);
                let ys = ConvExecutor::prepare(&wt, &sparse).expect("prepare").conv2d(&x);
                assert_eq!(
                    yd, ys,
                    "case {case}: F({m},3) C={c} K={k} {h}x{w} workers={workers}"
                );
            }
        }
    }
}

#[test]
fn prop_forward_batch_bit_identical_to_sequential() {
    // The serving tentpole property: for random small networks and every
    // backend family (dense, sparse, quant-sparse), `forward_batch` must
    // return exactly the per-image `forward` results for batch sizes
    // 1..=8 — and an image's logits must not depend on which batch it
    // rides in.
    use swcnn::executor::{ExecPolicy, Session};
    use swcnn::nn::graph::Synthetic;
    use swcnn::nn::{ConvLayer, FcLayer, Network};
    let mut rng = Rng::new(1017);
    for case in 0..4 {
        let c0 = 1 + rng.next_below(3);
        let k0 = 4 * (1 + rng.next_below(2));
        let k1 = 4 * (1 + rng.next_below(2));
        let hw = 8;
        let net = Network {
            name: "rand",
            input_hw: hw,
            input_ch: c0,
            convs: vec![
                ConvLayer { name: "c0", stage: 1, in_ch: c0, out_ch: k0, hw, r: 3 },
                ConvLayer { name: "c1", stage: 2, in_ch: k0, out_ch: k1, hw: hw / 2, r: 3 },
            ],
            fcs: vec![
                FcLayer { name: "f0", in_f: k1 * (hw / 4) * (hw / 4), out_f: 6 },
                FcLayer { name: "f1", in_f: 6, out_f: 4 },
            ],
        };
        for policy in [
            ExecPolicy::dense(2),
            ExecPolicy::sparse(2, 0.6),
            ExecPolicy::sparse(4, 0.7).with_bits(16),
        ] {
            let mut ex = Session::uniform(
                net.to_graph(),
                &mut Synthetic::new(900 + case as u64),
                policy,
            )
            .expect("session compiles")
            .with_max_batch(8);
            let images: Vec<Vec<f32>> =
                (0..8).map(|_| rng.gaussian_vec(c0 * hw * hw)).collect();
            let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
            let seq: Vec<Vec<f32>> = images
                .iter()
                .map(|im| ex.forward(im).expect("forward"))
                .collect();
            for n in 1..=8usize {
                let got = ex.forward_batch(&refs[..n]).expect("forward_batch");
                assert_eq!(
                    got,
                    seq[..n],
                    "case {case} {policy:?}: batch {n} != sequential"
                );
            }
            // Batch membership and position must not change an image.
            let shuffled = ex
                .forward_batch(&[refs[5], refs[1], refs[7]])
                .expect("forward_batch");
            assert_eq!(shuffled[0], seq[5], "case {case} {policy:?}");
            assert_eq!(shuffled[1], seq[1], "case {case} {policy:?}");
            assert_eq!(shuffled[2], seq[7], "case {case} {policy:?}");
        }
    }
}

#[test]
fn prop_cluster_matmul_equals_reference_random_dims() {
    let mut rng = Rng::new(1002);
    for case in 0..30 {
        let m = 1 + rng.next_below(40);
        let k = 1 + rng.next_below(40);
        let n = 1 + rng.next_below(40);
        let a = rng.gaussian_vec(m * k);
        let b = rng.gaussian_vec(k * n);
        let mut cl = Cluster::new(4);
        let c = cl.matmul(
            &BlockMatrix::new(&a, m, k, 4),
            &BlockMatrix::new(&b, k, n, 4),
        );
        // Reference.
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                assert!(
                    (c[i * n + j] - acc).abs() < 1e-3 * acc.abs().max(1.0),
                    "case {case} ({m},{k},{n}) at ({i},{j})"
                );
            }
        }
    }
}

#[test]
fn prop_sparse_cluster_equals_dense_on_decompressed() {
    let mut rng = Rng::new(1003);
    for case in 0..20 {
        let m = 4 * (1 + rng.next_below(6));
        let k = 4 * (1 + rng.next_below(6));
        let n = 4 * (1 + rng.next_below(6));
        let sparsity = rng.next_f64() * 0.95;
        let a = rng.gaussian_vec(m * k);
        let b = synthetic_sparse_matrix(&mut rng, k, n, 4, sparsity);
        let bcoo = Bcoo::compress(&b, k, n, 4);
        let mut cl_s = Cluster::new(4);
        let got = cl_s.matmul_sparse(&BlockMatrix::new(&a, m, k, 4), &bcoo);
        let dense = bcoo.decompress();
        assert_eq!(dense, b, "case {case}: BCOO roundtrip");
        let mut cl_d = Cluster::new(4);
        let want = cl_d.matmul(
            &BlockMatrix::new(&a, m, k, 4),
            &BlockMatrix::new(&dense, k, n, 4),
        );
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-3 * w.abs().max(1.0),
                "case {case} elem {i}: {g} vs {w} (sparsity {sparsity:.2})"
            );
        }
        // Cycle invariant: sparse path never slower than dense.
        assert!(cl_s.stats.cycles <= cl_d.stats.cycles, "case {case}");
    }
}

#[test]
fn prop_timing_model_equals_simulation_random() {
    let mut rng = Rng::new(1004);
    let t = BlockTiming::new(4);
    for case in 0..20 {
        let m = 4 * (1 + rng.next_below(8));
        let k = 4 * (1 + rng.next_below(8));
        let n = 4 * (1 + rng.next_below(8));
        let a = rng.gaussian_vec(m * k);
        let b = rng.gaussian_vec(k * n);
        let mut cl = Cluster::new(4);
        let _ = cl.matmul(
            &BlockMatrix::new(&a, m, k, 4),
            &BlockMatrix::new(&b, k, n, 4),
        );
        assert_eq!(
            t.dense_matmul_cycles(m, k, n),
            cl.stats.cycles,
            "case {case} ({m},{k},{n})"
        );
        let sparsity = rng.next_f64() * 0.9;
        let bs = synthetic_sparse_matrix(&mut rng, k, n, 4, sparsity);
        let bcoo = Bcoo::compress(&bs, k, n, 4);
        let mut cl_s = Cluster::new(4);
        let _ = cl_s.matmul_sparse(&BlockMatrix::new(&a, m, k, 4), &bcoo);
        assert_eq!(
            t.sparse_matmul_cycles(m, &bcoo),
            cl_s.stats.cycles,
            "case {case} sparse ({m},{k},{n}) p={sparsity:.2}"
        );
    }
}

#[test]
fn prop_zmorton_schedule_covers_and_is_bijective() {
    let mut rng = Rng::new(1005);
    for _ in 0..200 {
        let r = (rng.next_u64() & 0xFFFF) as u32;
        let c = (rng.next_u64() & 0xFFFF) as u32;
        assert_eq!(zmorton::decode(zmorton::encode(r, c)), (r, c));
    }
    for n in [2usize, 4, 8, 16] {
        let s = zmorton::schedule(n);
        let mut seen = std::collections::HashSet::new();
        for step in &s {
            let (ri, ki) = zmorton::decode(step.a_block);
            let (_, ci) = zmorton::decode(step.b_block);
            assert!(seen.insert((ri, ci, ki)));
        }
        assert_eq!(seen.len(), n * n * n);
    }
}

#[test]
fn prop_bcoo_roundtrip_random() {
    let mut rng = Rng::new(1006);
    for case in 0..50 {
        let rows = 4 * (1 + rng.next_below(16));
        let cols = 4 * (1 + rng.next_below(16));
        let sparsity = rng.next_f64() * 0.99;
        let mut mat = rng.gaussian_vec(rows * cols);
        prune_blocks(&mut mat, rows, cols, 4, sparsity);
        let bcoo = Bcoo::compress(&mat, rows, cols, 4);
        assert_eq!(bcoo.decompress(), mat, "case {case}");
        // nnz preserved.
        let nnz_dense = mat.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(bcoo.nnz(), nnz_dense, "case {case}");
        // Directory sorted (Z-Morton fetch order).
        assert!(bcoo.bn.windows(2).all(|w| w[0] < w[1]), "case {case}");
    }
}

#[test]
fn prop_transform_mode_never_multiplies() {
    let mut rng = Rng::new(1007);
    for (m, r) in [(2usize, 3usize), (4, 3), (6, 3)] {
        let l = winograd::tile_size(m, r);
        let (_, _, bt) = winograd::matrices(m, r);
        let b = bt.transpose2();
        let mut arr = SystolicArray::new(l);
        for _ in 0..10 {
            let d = rng.gaussian_vec(l * l);
            let _ = arr.winograd_transform(&d, b.data());
        }
        assert_eq!(arr.stats.macs, 0, "F({m},{r})");
        assert!(arr.stats.adds > 0);
    }
}

#[test]
fn prop_exact_rational_identity_fuzz() {
    // Random rational tiles through the exact generator: the 2-D identity
    // A^T[(G g G^T) ⊙ (B^T d B)]A == direct 2-D correlation, at f64.
    let mut rng = Rng::new(1008);
    for &(m, r) in &[(2usize, 3usize), (4, 3)] {
        let l = m + r - 1;
        for _ in 0..20 {
            let d = rand_tensor(&mut rng, &[1, l, l]);
            let g = rand_tensor(&mut rng, &[1, 1, r, r]);
            let direct = winograd::direct_conv2d(&d, &g);
            let wino = winograd::winograd_conv2d(&d, &g, m);
            assert!(
                direct.allclose(&wino, 1e-4, 1e-4),
                "F({m},{r}) diff {}",
                direct.max_abs_diff(&wino)
            );
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    use swcnn::util::json::Json;
    let mut rng = Rng::new(1009);
    // Generate random JSON trees, print, reparse, compare.
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.next_f64() * 1e6).round() / 8.0),
            3 => Json::Str(format!("s{}", rng.next_below(1000))),
            4 => Json::Arr((0..rng.next_below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..100 {
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, v, "case {case}: {text}");
    }
}

#[test]
fn prop_quantizer_error_bound() {
    use swcnn::quant::Quantizer;
    let mut rng = Rng::new(1010);
    for _ in 0..20 {
        let bits = 4 + rng.next_below(12) as u32;
        let data = rng.gaussian_vec(500);
        let q = Quantizer::calibrate(bits, &data);
        for &x in &data {
            assert!(
                (q.qdq(x) - x).abs() <= 0.5 * q.step() + 1e-6,
                "bits={bits}"
            );
        }
    }
}

#[test]
fn prop_maxpool2_ceil_mode_matches_scalar_oracle() {
    // Ceil-mode 2x2/stride-2 pooling on arbitrary (odd and even) spatial
    // sizes must match a from-scratch scalar oracle, for both the Tensor
    // form and the stacked-plane `_into` form on a dirty workspace.
    use swcnn::nn::{maxpool2, maxpool2_into};
    let mut rng = Rng::new(1020);
    for case in 0..60 {
        let c = 1 + rng.next_below(4);
        let h = 1 + rng.next_below(12);
        let w = 1 + rng.next_below(12);
        let x = rand_tensor(&mut rng, &[c, h, w]);
        let (oh, ow) = (h.div_ceil(2), w.div_ceil(2));
        // Scalar oracle: windows clipped at the bottom/right edges.
        let mut want = vec![f32::NEG_INFINITY; c * oh * ow];
        for cc in 0..c {
            for i in 0..h {
                for j in 0..w {
                    let dst = &mut want[(cc * oh + i / 2) * ow + j / 2];
                    *dst = dst.max(x.data()[(cc * h + i) * w + j]);
                }
            }
        }
        let got = maxpool2(&x);
        assert_eq!(got.shape(), &[c, oh, ow], "case {case}: {h}x{w}");
        assert_eq!(got.data(), &want[..], "case {case}: {h}x{w}");
        // The slice form over a dirty destination buffer.
        let mut dirty = vec![9.9f32; c * oh * ow];
        maxpool2_into(x.data(), c, h, w, &mut dirty);
        assert_eq!(&dirty[..], &want[..], "case {case} (into): {h}x{w}");
    }
}
