//! Graph/legacy equivalence: the redesigned graph + session API must be
//! **bit-identical** to the pre-redesign native path.
//!
//! The pre-redesign pipeline is reproduced here from first principles
//! (Tensor-level pad/conv/relu/pool over `Network::pool_after`, then the
//! FC head with `nn::synthetic_weights` — exactly what the removed
//! `NetworkExecutor::forward` shim hard-wired), so the tests never
//! depended on the shim for their oracle.  Also covers the
//! `save_weights`/`load_weights` roundtrip, the tuned-profile serving
//! path over a `Session`, and a non-VGG odd-spatial graph end-to-end.

use swcnn::coordinator::ServeBuilder;
use swcnn::executor::{ConvExecutor, ExecPolicy, Session};
use swcnn::nn::graph::{load_weights, save_weights, GraphBuilder, Synthetic};
use swcnn::nn::{self, vgg_tiny, vgg_tiny_network};
use swcnn::tensor::Tensor;
use swcnn::util::Rng;

/// The pre-redesign native forward pass, replicated independently: the
/// fixed pad -> conv -> relu [-> pool] ladder plus the FC head, on the
/// same seeded synthetic weight stream serving uses.  Takes one policy
/// per conv layer — exactly what the old per-layer executor consumed —
/// so both the uniform and the tuned configurations have an oracle.
fn legacy_forward_per_layer(policies: &[ExecPolicy], seed: u64, image: &[f32]) -> Vec<f32> {
    let net = vgg_tiny_network();
    let (weights, fcs) = nn::synthetic_weights(&net, seed);
    let mut convs: Vec<ConvExecutor> = net
        .convs
        .iter()
        .zip(weights.iter().zip(policies))
        .map(|(layer, (w, policy))| {
            ConvExecutor::prepare(w, &policy.for_layer(layer)).expect("prepare")
        })
        .collect();
    let hw = net.input_hw;
    let mut x = Tensor::from_vec(&[net.input_ch, hw, hw], image.to_vec());
    for i in 0..convs.len() {
        let padded = nn::pad_same(&x, nn::same_pad(net.convs[i].r));
        x = convs[i].conv2d(&padded);
        nn::relu_inplace(&mut x);
        if net.pool_after(i) {
            x = nn::maxpool2(&x);
        }
    }
    let mut a = x.data().to_vec();
    let n_fc = fcs.len();
    for (j, wm) in fcs.iter().enumerate() {
        let mut y = vec![0.0f32; wm.shape()[0]];
        nn::fc_into(wm, 1, &a, &mut y);
        if j + 1 < n_fc {
            nn::relu_slice(&mut y);
        }
        a = y;
    }
    a
}

/// The legacy oracle under one uniform policy.
fn legacy_forward(policy: ExecPolicy, seed: u64, image: &[f32]) -> Vec<f32> {
    legacy_forward_per_layer(&[policy; 5], seed, image)
}

/// The four policy families the executor distinguishes.
fn policy_families() -> [(&'static str, ExecPolicy); 4] {
    [
        ("dense", ExecPolicy::dense(2)),
        ("sparse", ExecPolicy::sparse(2, 0.7)),
        ("quant-dense", ExecPolicy::dense(2).with_bits(8)),
        ("quant-sparse", ExecPolicy::sparse(2, 0.7).with_bits(8)),
    ]
}

#[test]
fn session_bit_identical_to_legacy_path_all_backends() {
    let seed = 5u64;
    let mut rng = Rng::new(31);
    let images: Vec<Vec<f32>> = (0..4).map(|_| rng.gaussian_vec(3 * 32 * 32)).collect();
    for (name, policy) in policy_families() {
        let mut sess = Session::uniform(vgg_tiny(), &mut Synthetic::new(seed), policy)
            .expect("session compiles")
            .with_max_batch(4);
        // Batch 1: every image individually.
        let graph_logits: Vec<Vec<f32>> = images
            .iter()
            .map(|im| sess.forward(im).expect("forward"))
            .collect();
        for (im, got) in images.iter().zip(&graph_logits) {
            let want = legacy_forward(policy, seed, im);
            assert_eq!(got, &want, "{name}: graph vs legacy logits (batch 1)");
        }
        // Batch 4: one fused launch, still bit-identical per image.
        let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
        let batched = sess.forward_batch(&refs).expect("forward_batch");
        assert_eq!(batched, graph_logits, "{name}: batch 4 vs batch 1");
    }
}

#[test]
fn session_logits_bit_identical_across_vector_widths() {
    // The SIMD width knob is a pure performance choice: for every policy
    // family the served logits must equal the forced-scalar logits bit
    // for bit, at any width and with the threaded vector path too.
    use swcnn::winograd::VectorWidth;
    let seed = 11u64;
    let mut rng = Rng::new(41);
    let image = rng.gaussian_vec(3 * 32 * 32);
    for (name, policy) in policy_families() {
        let scalar = policy.with_vwidth(VectorWidth::Scalar);
        let want = Session::uniform(vgg_tiny(), &mut Synthetic::new(seed), scalar)
            .expect("scalar session")
            .forward(&image)
            .expect("forward");
        assert_eq!(want, legacy_forward(scalar, seed, &image), "{name}: oracle");
        for vw in VectorWidth::ALL {
            for workers in [1, 3] {
                let wide = policy.with_vwidth(vw).with_workers(workers);
                let got = Session::uniform(vgg_tiny(), &mut Synthetic::new(seed), wide)
                    .expect("vector session")
                    .forward(&image)
                    .expect("forward");
                assert_eq!(got, want, "{name}: width {vw}, {workers} workers");
            }
        }
    }
}

#[test]
fn weights_roundtrip_preserves_logits_across_backends() {
    let seed = 9u64;
    let graph = vgg_tiny();
    let path = std::env::temp_dir().join(format!(
        "swcnn_graph_roundtrip_{}.bin",
        std::process::id()
    ));
    save_weights(&path, &graph, &mut Synthetic::new(seed)).expect("save");
    let mut rng = Rng::new(33);
    let image = rng.gaussian_vec(3 * 32 * 32);
    for (name, policy) in policy_families() {
        let mut synth = Session::uniform(vgg_tiny(), &mut Synthetic::new(seed), policy)
            .expect("synthetic session");
        let mut filed = Session::uniform(
            vgg_tiny(),
            &mut load_weights(&path).expect("load"),
            policy,
        )
        .expect("file-backed session");
        assert_eq!(
            synth.forward(&image).expect("forward"),
            filed.forward(&image).expect("forward"),
            "{name}: file-backed weights must serve bit-identically"
        );
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn served_session_bit_identical_to_legacy_default_config() {
    // Acceptance gate: graph-built vgg_tiny behind the InferenceServer
    // equals the pre-redesign native path under the default config.
    let seed = 7u64;
    let policy = ExecPolicy::sparse(2, 0.7);
    let mut rng = Rng::new(35);
    let image = rng.gaussian_vec(3 * 32 * 32);
    let want = legacy_forward(policy, seed, &image);
    let session =
        Session::uniform(vgg_tiny(), &mut Synthetic::new(seed), policy).expect("session");
    let server = ServeBuilder::new(session).start().expect("start");
    let got = server.infer(image).expect("infer");
    assert_eq!(got, want, "served logits must match the pre-redesign path");
}

#[test]
fn served_session_bit_identical_under_tuned_profile() {
    // Acceptance gate: the tuned (TuneProfile) configuration over the
    // graph engine reproduces the pre-redesign per-layer engine exactly.
    use swcnn::tuner::{TuneOptions, Tuner};
    let seed = 7u64;
    let base = ExecPolicy::sparse(2, 0.7);
    let profile = Tuner::new(vgg_tiny(), base, seed)
        .with_options(TuneOptions {
            calibrate: false,
            ..TuneOptions::default()
        })
        .tune()
        .expect("tune");
    let policies = profile
        .policies_for(&vgg_tiny(), &base)
        .expect("profile matches");
    let session =
        Session::build(vgg_tiny(), &mut Synthetic::new(seed), &policies).expect("session");
    let server = ServeBuilder::new(session)
        .profile(profile)
        .start()
        .expect("start tuned");
    let mut rng = Rng::new(37);
    let image = rng.gaussian_vec(3 * 32 * 32);
    // The oracle is the pre-redesign per-layer path under the SAME tuned
    // policies (tuning may change a layer's F(m, 3), which legitimately
    // changes the transform arithmetic — the invariant is that the graph
    // engine reproduces the legacy engine configuration for
    // configuration, bit for bit).
    let want = legacy_forward_per_layer(&policies, seed, &image);
    let got = server.infer(image).expect("infer");
    assert_eq!(
        got, want,
        "tuned serving must be bit-identical to the pre-redesign tuned path"
    );
}

#[test]
fn non_vgg_odd_graph_serves_end_to_end() {
    // Acceptance gate: a conv -> pool -> conv graph with an odd spatial
    // size runs through the same public API, including the server.
    let graph = || {
        GraphBuilder::new("oddnet", (3, 9, 9))
            .pad(1)
            .conv2d("c0", 8, 3)
            .relu()
            .maxpool2() // 9x9 -> 5x5 in ceil mode
            .pad(1)
            .conv2d("c1", 8, 3)
            .relu()
            .maxpool2() // 5x5 -> 3x3
            .flatten()
            .fc("head", 4)
            .build()
            .expect("odd graph builds")
    };
    let mut sess = Session::uniform(graph(), &mut Synthetic::new(3), ExecPolicy::sparse(2, 0.6))
        .expect("compiles")
        .with_max_batch(2);
    let mut rng = Rng::new(39);
    let a = rng.gaussian_vec(3 * 9 * 9);
    let b = rng.gaussian_vec(3 * 9 * 9);
    let ya = sess.forward(&a).expect("forward");
    let yb = sess.forward(&b).expect("forward");
    assert_eq!(ya.len(), 4);
    assert!(ya.iter().all(|v| v.is_finite()));
    assert_eq!(
        sess.forward_batch(&[&a, &b]).expect("batch"),
        vec![ya.clone(), yb],
        "odd-size batch must equal sequential"
    );
    let session =
        Session::uniform(graph(), &mut Synthetic::new(3), ExecPolicy::sparse(2, 0.6))
            .expect("compiles");
    let server = ServeBuilder::new(session).start().expect("start");
    assert_eq!(server.input_elements(), 3 * 9 * 9);
    assert_eq!(server.output_elements(), 4);
    assert_eq!(server.infer(a).expect("infer"), ya, "served == direct");
    // And a bad request is refused, not fatal: the server keeps serving.
    assert!(server.infer(vec![0.0; 5]).is_err());
    assert_eq!(server.infer(b.clone()).expect("infer").len(), 4);
}
