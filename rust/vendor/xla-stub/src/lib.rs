//! Offline stand-in for the `xla` crate (PJRT C API bindings).
//!
//! The offline crate set cannot ship the real `xla` crate (it links the
//! PJRT runtime), but the `pjrt` feature gate still has to **compile** so
//! CI catches gate breakage before a real deployment hits it.  This shim
//! mirrors exactly the surface `swcnn::runtime` uses — `PjRtClient`,
//! `PjRtLoadedExecutable`, `PjRtBuffer`, `Literal`, `ArrayShape`,
//! `HloModuleProto`, `XlaComputation` — and fails at the earliest runtime
//! entry point ([`PjRtClient::cpu`]) with a clear message.  Swapping in
//! the real crate is a one-line change in `rust/Cargo.toml` (point the
//! `xla` path dependency at a vendored copy of the real bindings).
//!
//! Nothing here ever executes: every constructor chain begins at
//! `PjRtClient::cpu()`, which returns [`Error`].  The other types exist
//! so the typed call sites in `runtime::exec` type-check unchanged.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's `?`-compatibility: implements
/// [`std::error::Error`], so `anyhow` call sites convert transparently.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} unavailable — this build links the offline xla stub; vendor \
         the real xla crate and point rust/Cargo.toml's `xla` path at it"
    )))
}

/// Array shape of a literal (dims only; element type is always f32 in
/// this project's artifacts).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host tensor handle.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

/// Element types [`Literal::to_vec`] can extract.  The real crate is
/// generic over its element trait; the stub only needs f32.
pub trait NativeType: Sized {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

impl Literal {
    /// A rank-1 literal over host data.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape to {dims:?} ({n} elements) from {} elements",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    /// Copy out as a flat host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Destructure a tuple literal.  Stub literals are never tuples
    /// (nothing executes), so this is unreachable in practice.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("tuple literals")
    }
}

/// Parsed HLO module (text form).  The stub validates the file exists so
/// manifest errors still surface at the right call site.
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        match std::fs::read_to_string(path) {
            Ok(text) => Ok(Self { _text: text }),
            Err(e) => Err(Error(format!("reading {}: {e}", path.display()))),
        }
    }
}

/// A computation handle built from an HLO proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("device buffers")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments.  Generic over the argument type
    /// like the real crate (`execute::<Literal>`); the stub never runs.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execution")
    }
}

/// PJRT client.  The stub fails here — the earliest entry point — so
/// every downstream path reports the same actionable message.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu()")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compilation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_actionable_message() {
        let err = PjRtClient::cpu().expect_err("stub must not construct");
        let msg = err.to_string();
        assert!(msg.contains("xla stub"), "{msg}");
        assert!(msg.contains("vendor the real xla crate"), "{msg}");
    }

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).expect("reshape");
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3, 3]).is_err(), "element-count mismatch");
    }

    #[test]
    fn hlo_text_loads_and_missing_file_errors() {
        let path = std::env::temp_dir().join(format!("xla_stub_{}.hlo", std::process::id()));
        std::fs::write(&path, "HloModule m").unwrap();
        assert!(HloModuleProto::from_text_file(&path).is_ok());
        let _ = std::fs::remove_file(&path);
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo").is_err());
    }
}
