//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The offline crate set has no registry access, so this vendored shim
//! provides exactly the surface `swcnn` uses: `Error`, `Result<T>`, the
//! `anyhow!` / `bail!` macros, and the `Context` extension trait.  Errors
//! are message-only (context is folded into the message eagerly) — enough
//! for a CLI and test suite, without the real crate's backtrace machinery.

use std::fmt;

/// A message-carrying error.  Deliberately does **not** implement
/// `std::error::Error`, so the blanket `From<E: std::error::Error>`
/// conversion below cannot overlap with the identity `From<T> for T`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
        }
    }

    /// Prepend a context layer, anyhow-style ("context: cause").
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Self::msg(e)
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to the error branch of a `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/3f9a")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_and_context() {
        let e: Error = anyhow!("bad {}", 7);
        assert_eq!(e.to_string(), "bad 7");
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r: Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| format!("step {}", 2)).unwrap_err();
        assert!(e.to_string().starts_with("step 2: "));
    }

    #[test]
    fn bail_returns_err() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
    }
}
