//! Replica-pool serving bench: sharded dispatch over one shared
//! compiled model, driven closed-loop at three pool widths.
//!
//!   cargo bench --bench serving_pool
//!
//! One `CompiledModel` (sparse vgg_tiny) is compiled **once** and the
//! same `Arc` serves pools of 1, 2, and 4 replicas — the pool's whole
//! premise is that replicas cost scratch memory, not filter banks.
//! Each width is driven closed-loop with `WAVE` requests in flight
//! (waves of async admissions, then a full drain), so the sharder has
//! real concurrency to spread and every replica fuses full batches.
//!
//! Results go to `BENCH_serving_pool.json` (bench working directory).
//! CI gates the headline `pool_speedup_r4_vs_r1` against a committed
//! baseline, and the bench itself asserts the acceptance gates: pool
//! outputs bit-identical to a direct `Session::forward` over the same
//! model, and four replicas strictly out-serving one.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use swcnn::bench::print_table;
use swcnn::coordinator::PoolBuilder;
use swcnn::executor::{CompiledModel, ExecPolicy, Session};
use swcnn::nn::graph::Synthetic;
use swcnn::nn::vgg_tiny;
use swcnn::util::json::Json;
use swcnn::util::Rng;

const SPARSITY: f64 = 0.7;
const REPLICAS: [usize; 3] = [1, 2, 4];
const MAX_BATCH: usize = 8;
const WAVE: usize = 32;
const WAVES: usize = 4;
const WARMUP_WAVES: usize = 1;

/// One measured pool width, ready for the table and the JSON.
struct Run {
    replicas: usize,
    achieved_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
    dispatch: Vec<u64>,
    steals: Vec<u64>,
}

fn main() {
    let policy = ExecPolicy::sparse(2, SPARSITY);
    let model = Arc::new(
        CompiledModel::uniform(vgg_tiny(), &mut Synthetic::new(7), policy)
            .expect("vgg_tiny compiles"),
    );
    let mut direct = Session::from_model(Arc::clone(&model));
    let mut rng = Rng::new(42);
    let image = rng.gaussian_vec(direct.input_elements());
    let want = direct.forward(&image).expect("direct forward");

    let runs: Vec<Run> = REPLICAS
        .iter()
        .map(|&r| drive_pool(&model, r, &image, &want))
        .collect();

    let speedup_r4 = runs[2].achieved_rps / runs[0].achieved_rps;
    let speedup_r2 = runs[1].achieved_rps / runs[0].achieved_rps;
    let table: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            vec![
                format!("pool_r{}", r.replicas),
                format!("{:.1} req/s", r.achieved_rps),
                format!("{:.2} ms", r.p50_ms),
                format!("{:.2} ms", r.p99_ms),
                format!("{:.2}", r.mean_batch),
                format!("{:?}", r.dispatch),
                format!("{:?}", r.steals),
            ]
        })
        .collect();
    print_table(
        &format!(
            "replica-pool serving (sparse {SPARSITY} vgg_tiny, one shared \
             CompiledModel, {WAVE} in flight, fused batches <= {MAX_BATCH})"
        ),
        &[
            "pool", "achieved", "p50", "p99", "mean batch", "dispatch", "steals",
        ],
        &table,
    );
    println!("4 replicas vs 1: {speedup_r4:.2}x throughput ({speedup_r2:.2}x at 2)");
    write_json(&runs, speedup_r2, speedup_r4);

    // The scaling gate (CI runs this bench): four replicas over the
    // same shared filter banks must out-serve one, or the pool is
    // sharding overhead without buying parallel service.
    assert!(
        speedup_r4 > 1.0,
        "a 4-replica pool must beat a 1-replica pool (got {speedup_r4:.2}x)"
    );
}

/// Drive one pool width closed-loop and return its measured shape.
///
/// Gates correctness before measuring: the pool's logits must equal
/// the direct forward bit for bit — a fast-but-wrong pool fails here.
fn drive_pool(model: &Arc<CompiledModel>, replicas: usize, image: &[f32], want: &[f32]) -> Run {
    let pool = PoolBuilder::new(Arc::clone(model), replicas)
        .max_batch(MAX_BATCH)
        .window(Duration::from_millis(2))
        .start()
        .expect("pool starts");

    let got = pool.infer(image.to_vec()).expect("pool serves");
    assert_eq!(
        got, *want,
        "pool serving must be bit-identical to a direct forward"
    );

    for _ in 0..WARMUP_WAVES {
        let replies: Vec<_> = (0..WAVE)
            .map(|_| pool.infer_async(image.to_vec()).expect("warmup admit"))
            .collect();
        for reply in replies {
            reply.recv().expect("warmup reply").expect("warmup logits");
        }
    }

    let mut lats = Vec::with_capacity(WAVES * WAVE);
    let t0 = Instant::now();
    for _ in 0..WAVES {
        let sent: Vec<_> = (0..WAVE)
            .map(|_| {
                let t = Instant::now();
                (pool.infer_async(image.to_vec()).expect("admit"), t)
            })
            .collect();
        for (reply, t_send) in sent {
            let logits = reply.recv().expect("reply").expect("logits");
            assert_eq!(logits, *want, "every served request must match the direct forward");
            lats.push(t_send.elapsed().as_secs_f64());
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();

    let (mean_batch, dispatch, steals) = {
        let m = pool.metrics.lock().expect("metrics lock");
        (
            m.mean_batch(),
            m.replica_dispatch().to_vec(),
            m.replica_steals().to_vec(),
        )
    };
    pool.shutdown(true);

    Run {
        replicas,
        achieved_rps: (WAVES * WAVE) as f64 / elapsed,
        p50_ms: percentile_ms(&mut lats, 0.50),
        p99_ms: percentile_ms(&mut lats, 0.99),
        mean_batch,
        dispatch,
        steals,
    }
}

/// Nearest-rank percentile in milliseconds; sorts in place.
fn percentile_ms(lats: &mut [f64], p: f64) -> f64 {
    if lats.is_empty() {
        return f64::NAN;
    }
    lats.sort_by(|a, b| a.total_cmp(b));
    let idx = ((lats.len() as f64 - 1.0) * p).round() as usize;
    lats[idx.min(lats.len() - 1)] * 1e3
}

/// `BENCH_serving_pool.json`: one row per pool width with achieved
/// req/s, p50/p99 milliseconds, and the per-replica dispatch/steal
/// counters, plus the headline 4-vs-1 throughput multiple CI gates.
fn write_json(runs: &[Run], speedup_r2: f64, speedup_r4: f64) {
    let rows: Vec<Json> = runs
        .iter()
        .map(|r| {
            Json::Obj(BTreeMap::from([
                ("name".to_string(), Json::Str(format!("pool_r{}", r.replicas))),
                ("replicas".to_string(), Json::Num(r.replicas as f64)),
                ("achieved_rps".to_string(), Json::Num(r.achieved_rps)),
                ("p50_ms".to_string(), Json::Num(r.p50_ms)),
                ("p99_ms".to_string(), Json::Num(r.p99_ms)),
                ("mean_batch".to_string(), Json::Num(r.mean_batch)),
                (
                    "replica_dispatch".to_string(),
                    Json::Arr(r.dispatch.iter().map(|&d| Json::Num(d as f64)).collect()),
                ),
                (
                    "replica_steals".to_string(),
                    Json::Arr(r.steals.iter().map(|&s| Json::Num(s as f64)).collect()),
                ),
            ]))
        })
        .collect();
    let top = BTreeMap::from([
        ("bench".to_string(), Json::Str("serving_pool".to_string())),
        ("schema".to_string(), Json::Num(1.0)),
        ("network".to_string(), Json::Str("vgg_tiny".to_string())),
        (
            "policy".to_string(),
            Json::Str(format!("sparse F(2,3) p={SPARSITY}")),
        ),
        ("in_flight".to_string(), Json::Num(WAVE as f64)),
        ("results".to_string(), Json::Arr(rows)),
        ("pool_speedup_r2_vs_r1".to_string(), Json::Num(speedup_r2)),
        ("pool_speedup_r4_vs_r1".to_string(), Json::Num(speedup_r4)),
    ]);
    let path = "BENCH_serving_pool.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
