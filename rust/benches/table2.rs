//! Bench/repro for Table 2: end-to-end throughput, DSP utilization, and
//! power efficiency of our configuration vs the paper's reported row.
//!
//! Absolute numbers come from the cycle-level simulator at the paper's
//! 150 MHz clock; 8-bit mode packs two MACs per DSP slice (the usual
//! DSP48 trick the paper's 8/16-bit rows encode).
//!
//!   cargo bench --bench table2

use swcnn::accelerator::{simulate_dense, simulate_sparse, JOULES_PER_UNIT};
use swcnn::bench::{print_table, time_it};
use swcnn::memory::EnergyTable;
use swcnn::nn::vgg16_network;
use swcnn::resources::{paper_configuration, XCVU095};
use swcnn::scheduler::AcceleratorConfig;

fn main() {
    let cfg = AcceleratorConfig::paper();
    let table = EnergyTable::default();
    let net = vgg16_network();

    let t_dense = time_it(1, 5, || {
        std::hint::black_box(simulate_dense(&net, &cfg, &table));
    });
    let dense = simulate_dense(&net, &cfg, &table);
    let sparse = simulate_sparse(&net, &cfg, &table, 0.9, 7);

    // 16-bit fixed: one MAC per DSP per cycle (the simulated baseline).
    let gops16 = dense.gops();
    // 8-bit fixed: two MACs per DSP slice -> 2x effective throughput.
    let gops8 = 2.0 * gops16;
    // Projected sparse 8-bit (paper: 921.6 = 2 x 460.8).
    let gops8_sparse = 2.0 * sparse.gops();
    // Paper's 55.9 Gops/s/W is the 8-bit throughput over the board power.
    let eff = 2.0 * dense.gops_per_watt(JOULES_PER_UNIT);

    let u = paper_configuration();
    let rows = vec![
        vec![
            "throughput 16-bit (Gops/s)".into(),
            "230.4".into(),
            format!("{gops16:.1}"),
        ],
        vec![
            "throughput 8-bit (Gops/s)".into(),
            "460.8".into(),
            format!("{gops8:.1}"),
        ],
        vec![
            "projected 8-bit sparse (Gops/s)".into(),
            "921.6".into(),
            format!("{gops8_sparse:.1}"),
        ],
        vec![
            "DSP utilization".into(),
            "(512+256)/768".into(),
            format!("({}+{})/{}", u.dsp_arith, u.dsp_transform, XCVU095.dsps),
        ],
        vec![
            "power efficiency (Gops/s/W)".into(),
            "55.9".into(),
            format!("{eff:.1}"),
        ],
        vec![
            "frequency (MHz)".into(),
            "150".into(),
            format!("{:.0}", cfg.freq_mhz),
        ],
    ];
    print_table(
        "Table 2 reproduction (our impl. column)",
        &["metric", "paper", "ours (simulated)"],
        &rows,
    );
    println!(
        "\nsimulator wall time: {:.1} ms per full-VGG16 dense run (n={})",
        t_dense.mean * 1e3,
        t_dense.n
    );
    println!(
        "shape checks: sparse/dense speedup {:.2}x (paper ~2x on projected",
        gops8_sparse / gops8
    );
    println!("throughput, ~5x on latency for the best case of Fig. 7b).");
}
