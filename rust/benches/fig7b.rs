//! Bench/repro for Fig. 7(b): VGG16 inference latency for m ∈ {2, 4, 6}
//! and block sparsity 60-90% — the cycle-level simulator sweep, including
//! the paper's ~5x best-case speedup.
//!
//!   cargo bench --bench fig7b

use swcnn::accelerator::{latency_sweep, simulate_dense};
use swcnn::bench::{print_table, time_it};
use swcnn::memory::EnergyTable;
use swcnn::nn::vgg16_network;
use swcnn::scheduler::AcceleratorConfig;

fn main() {
    let net = vgg16_network();
    let cfg = AcceleratorConfig::paper();
    let table = EnergyTable::default();

    let stats = time_it(0, 3, || {
        std::hint::black_box(latency_sweep(&net, &cfg, &table, &[2], &[0.9]));
    });

    let rows_raw = latency_sweep(&net, &cfg, &table, &[2, 4, 6], &[0.6, 0.7, 0.8, 0.9]);
    let dense_m2 = rows_raw
        .iter()
        .find(|r| r.0 == 2 && r.1 == 0.0)
        .unwrap()
        .2;
    let rows: Vec<Vec<String>> = rows_raw
        .iter()
        .map(|&(m, p, s)| {
            vec![
                m.to_string(),
                if p == 0.0 {
                    "dense".into()
                } else {
                    format!("{:.0}%", p * 100.0)
                },
                format!("{:.2}", s * 1e3),
                format!("{:.2}x", dense_m2 / s),
            ]
        })
        .collect();
    print_table(
        "Fig. 7(b): VGG16 latency vs m and sparsity (vs dense m=2)",
        &["m", "sparsity", "latency (ms)", "speedup"],
        &rows,
    );

    // The paper's "almost 5x" is sparse-vs-dense at fixed m; report both.
    let mut within_best = 0.0f64;
    for m in [2usize, 4, 6] {
        let dense = rows_raw.iter().find(|r| r.0 == m && r.1 == 0.0).unwrap().2;
        for r in rows_raw.iter().filter(|r| r.0 == m && r.1 > 0.0) {
            within_best = within_best.max(dense / r.2);
        }
    }
    let cross = rows_raw
        .iter()
        .map(|r| dense_m2 / r.2)
        .fold(0.0f64, f64::max);
    println!(
        "\nbest within-m sparse speedup: {within_best:.2}x (paper: 'almost 5x'); \
         best vs dense m=2 incl. m-change: {cross:.2}x"
    );
    let dense = simulate_dense(&net, &cfg, &table);
    println!(
        "dense VGG16: {:.2} ms -> {:.0} img/s @150 MHz | sweep cost {:.2} s/point",
        dense.total_seconds * 1e3,
        1.0 / dense.total_seconds,
        stats.mean
    );
}
