//! Network-serving load bench: the TCP front-end on sparse vgg_tiny,
//! driven closed-loop and open-loop over a real socket.
//!
//!   cargo bench --bench serving_net
//!
//! Three load shapes against one `NetServer` (loopback, fused batches
//! of up to 8 over a 2 ms window):
//!
//! - **closed-loop depth 1**: one request in flight — the per-request
//!   floor a synchronous caller sees (latency includes the batching
//!   window);
//! - **closed-loop depth 8**: eight requests pipelined on one
//!   connection — admission-ordered responses let the batcher fuse
//!   them, which is the whole point of the front-end;
//! - **open-loop** at two offered rates (50% and 90% of the pipelined
//!   throughput): a paced sender thread and a receiving main thread,
//!   so queueing delay shows up in the percentiles instead of being
//!   absorbed by the load generator.
//!
//! Results go to `BENCH_serving_net.json` (bench working directory).
//! CI gates the headline `pipelined_speedup_vs_closed` against a
//! committed floor, and the bench itself asserts the acceptance gates:
//! served logits bit-identical to a local `Session::forward`, and
//! pipelined throughput strictly above closed-loop depth 1.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use swcnn::bench::print_table;
use swcnn::coordinator::net::{wire, NetClient, NetServer};
use swcnn::coordinator::ServeBuilder;
use swcnn::executor::{ExecPolicy, Session};
use swcnn::nn::graph::Synthetic;
use swcnn::nn::vgg_tiny;
use swcnn::util::json::Json;
use swcnn::util::Rng;

const SPARSITY: f64 = 0.7;
const WARMUP: usize = 8;
const CLOSED_N: usize = 64;
const DEPTH: usize = 8;
const PIPELINED_N: usize = 64;
const OPEN_N: usize = 64;
const OPEN_FRACTIONS: [f64; 2] = [0.5, 0.9];

/// One measured load shape, ready for the table and the JSON.
struct Run {
    name: String,
    offered_rps: Option<f64>,
    achieved_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    errors: u64,
}

fn main() {
    let policy = ExecPolicy::sparse(2, SPARSITY);
    let mut direct =
        Session::uniform(vgg_tiny(), &mut Synthetic::new(7), policy).expect("vgg_tiny compiles");
    let mut rng = Rng::new(42);
    let image = rng.gaussian_vec(direct.input_elements());
    let want = direct.forward(&image).expect("direct forward");

    let server = ServeBuilder::new(
        Session::uniform(vgg_tiny(), &mut Synthetic::new(7), policy).expect("vgg_tiny compiles"),
    )
    .max_batch(DEPTH)
    .window(Duration::from_millis(2))
    .start()
    .expect("server starts");
    let net = NetServer::bind("127.0.0.1:0", server).expect("bind loopback");
    let addr = net.local_addr();

    // Correctness gate first: a fast-but-wrong front-end must fail the
    // bench.  The served logits must equal the local session's bit for
    // bit.
    let mut client = NetClient::connect(addr).expect("connect");
    let got = client.infer(&image).expect("served");
    assert_eq!(got, want, "network serving must be bit-identical");
    for _ in 0..WARMUP {
        client.infer(&image).expect("warmup");
    }

    // -- closed loop, depth 1 --------------------------------------------
    let mut lats = Vec::with_capacity(CLOSED_N);
    let t0 = Instant::now();
    for _ in 0..CLOSED_N {
        let t = Instant::now();
        client.infer(&image).expect("closed-loop request");
        lats.push(t.elapsed().as_secs_f64());
    }
    let closed = Run {
        name: "net_closed_depth1".into(),
        offered_rps: None,
        achieved_rps: CLOSED_N as f64 / t0.elapsed().as_secs_f64(),
        p50_ms: percentile_ms(&mut lats, 0.50),
        p99_ms: percentile_ms(&mut lats, 0.99),
        errors: 0,
    };

    // -- closed loop, depth 8 (pipelined) --------------------------------
    let mut lats = Vec::with_capacity(PIPELINED_N);
    let t0 = Instant::now();
    for _ in 0..PIPELINED_N / DEPTH {
        let mut sent = Vec::with_capacity(DEPTH);
        for _ in 0..DEPTH {
            let id = client.send_infer(&image, 0).expect("pipelined send");
            sent.push((id, Instant::now()));
        }
        for (id, t_send) in sent {
            match client.recv().expect("pipelined response") {
                wire::Response::Logits { id: got, .. } => {
                    assert_eq!(got, id, "responses must arrive in request order");
                    lats.push(t_send.elapsed().as_secs_f64());
                }
                other => panic!("pipelined request {id} failed: {other:?}"),
            }
        }
    }
    let pipelined = Run {
        name: format!("net_pipelined_depth{DEPTH}"),
        offered_rps: None,
        achieved_rps: PIPELINED_N as f64 / t0.elapsed().as_secs_f64(),
        p50_ms: percentile_ms(&mut lats, 0.50),
        p99_ms: percentile_ms(&mut lats, 0.99),
        errors: 0,
    };

    // -- open loop at two offered rates ----------------------------------
    let mut runs = vec![closed, pipelined];
    for frac in OPEN_FRACTIONS {
        let offered = runs[1].achieved_rps * frac;
        runs.push(open_loop(addr, &image, offered, frac));
    }

    // Batch-size distribution straight from the server's own counters.
    let metrics = Json::parse(&client.metrics_json().expect("metrics over TCP"))
        .expect("metrics endpoint serves valid JSON");
    let mean_batch = metrics
        .req("mean_batch")
        .ok()
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    let histogram = metrics
        .get("batch_histogram")
        .cloned()
        .unwrap_or(Json::Arr(Vec::new()));

    let speedup = runs[1].achieved_rps / runs[0].achieved_rps;
    let table: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                r.offered_rps
                    .map(|o| format!("{o:.1} req/s"))
                    .unwrap_or_else(|| "closed".into()),
                format!("{:.1} req/s", r.achieved_rps),
                format!("{:.2} ms", r.p50_ms),
                format!("{:.2} ms", r.p99_ms),
                r.errors.to_string(),
            ]
        })
        .collect();
    print_table(
        &format!(
            "network serving (sparse {SPARSITY} vgg_tiny over loopback, \
             fused batches <= {DEPTH}, mean batch {mean_batch:.2})"
        ),
        &["load shape", "offered", "achieved", "p50", "p99", "errors"],
        &table,
    );
    println!("pipelined vs closed-loop depth 1: {speedup:.2}x throughput");
    write_json(&runs, speedup, mean_batch, histogram);

    // The batching gate (CI runs this bench): pipelined traffic through
    // the same socket must beat one-at-a-time round trips, or the
    // front-end is adding a network hop without buying batch fusion.
    assert!(
        speedup > 1.0,
        "pipelined depth-{DEPTH} must beat closed-loop depth 1 (got {speedup:.2}x)"
    );
    net.shutdown();
}

/// Open-loop shape: a sender thread paces `OPEN_N` requests at
/// `offered` req/s on its own half of the connection while the caller
/// receives; latency spans send -> response, so queueing shows up.
fn open_loop(addr: std::net::SocketAddr, image: &[f32], offered: f64, frac: f64) -> Run {
    let stream = TcpStream::connect(addr).expect("open-loop connect");
    stream.set_nodelay(true).expect("nodelay");
    let mut wstream = stream.try_clone().expect("clone for sender");
    let mut rstream = stream;
    let (times_tx, times_rx) = mpsc::channel::<(u64, Instant)>();
    let interval = Duration::from_secs_f64(1.0 / offered);
    let image = image.to_vec();
    let sender = std::thread::spawn(move || {
        let mut frame = Vec::new();
        let start = Instant::now();
        for i in 0..OPEN_N as u64 {
            let due = start + interval.mul_f64(i as f64);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            frame.clear();
            wire::encode_request(
                &wire::Request::Infer {
                    id: i,
                    model: 0,
                    deadline_ms: 0,
                    image: image.clone(),
                },
                &mut frame,
            );
            if times_tx.send((i, Instant::now())).is_err() {
                return;
            }
            if wstream.write_all(&frame).is_err() {
                return;
            }
        }
    });

    let mut buf = Vec::new();
    let mut chunk = [0u8; 16384];
    let mut lats = Vec::with_capacity(OPEN_N);
    let mut errors = 0u64;
    let t0 = Instant::now();
    for _ in 0..OPEN_N {
        let (id, t_send) = times_rx.recv().expect("sender alive");
        let resp = loop {
            match wire::decode_response(&buf) {
                Ok(Some((resp, used))) => {
                    buf.drain(..used);
                    break resp;
                }
                Ok(None) => {
                    let n = rstream.read(&mut chunk).expect("open-loop read");
                    assert!(n > 0, "server closed mid-bench");
                    buf.extend_from_slice(&chunk[..n]);
                }
                Err(e) => panic!("open-loop wire error: {e}"),
            }
        };
        match resp {
            wire::Response::Logits { id: got, .. } => {
                assert_eq!(got, id, "responses must arrive in request order");
                lats.push(t_send.elapsed().as_secs_f64());
            }
            wire::Response::Error { id: got, .. } => {
                assert_eq!(got, id);
                errors += 1;
            }
            other => panic!("open-loop request {id}: unexpected {other:?}"),
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    sender.join().expect("sender thread");
    Run {
        name: format!("net_open_{:.0}pct", frac * 100.0),
        offered_rps: Some(offered),
        achieved_rps: (OPEN_N as u64 - errors) as f64 / elapsed,
        p50_ms: percentile_ms(&mut lats, 0.50),
        p99_ms: percentile_ms(&mut lats, 0.99),
        errors,
    }
}

/// Nearest-rank percentile in milliseconds; sorts in place.
fn percentile_ms(lats: &mut [f64], p: f64) -> f64 {
    if lats.is_empty() {
        return f64::NAN;
    }
    lats.sort_by(|a, b| a.total_cmp(b));
    let idx = ((lats.len() as f64 - 1.0) * p).round() as usize;
    lats[idx.min(lats.len() - 1)] * 1e3
}

/// `BENCH_serving_net.json`: one row per load shape with achieved
/// req/s and p50/p99 milliseconds, the server-side batch distribution,
/// and the headline pipelined-vs-closed throughput multiple CI gates.
fn write_json(runs: &[Run], speedup: f64, mean_batch: f64, histogram: Json) {
    let rows: Vec<Json> = runs
        .iter()
        .map(|r| {
            let mut row = BTreeMap::from([
                ("name".to_string(), Json::Str(r.name.clone())),
                ("achieved_rps".to_string(), Json::Num(r.achieved_rps)),
                ("p50_ms".to_string(), Json::Num(r.p50_ms)),
                ("p99_ms".to_string(), Json::Num(r.p99_ms)),
                ("errors".to_string(), Json::Num(r.errors as f64)),
            ]);
            if let Some(o) = r.offered_rps {
                row.insert("offered_rps".to_string(), Json::Num(o));
            }
            Json::Obj(row)
        })
        .collect();
    let top = BTreeMap::from([
        ("bench".to_string(), Json::Str("serving_net".to_string())),
        ("schema".to_string(), Json::Num(1.0)),
        ("network".to_string(), Json::Str("vgg_tiny".to_string())),
        (
            "policy".to_string(),
            Json::Str(format!("sparse F(2,3) p={SPARSITY}")),
        ),
        ("transport".to_string(), Json::Str("tcp loopback".to_string())),
        ("results".to_string(), Json::Arr(rows)),
        ("mean_batch".to_string(), Json::Num(mean_batch)),
        ("batch_histogram".to_string(), histogram),
        (
            "pipelined_speedup_vs_closed".to_string(),
            Json::Num(speedup),
        ),
    ]);
    let path = "BENCH_serving_net.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
