//! Bench/repro for Fig. 6: relative data-movement energy per memory
//! hierarchy level (Sze et al. CICC'17 as cited by the paper), plus a
//! measured-traffic demo: the same matmul's energy under three layouts.
//!
//!   cargo bench --bench fig6

use swcnn::bench::{print_table, time_it};
use swcnn::memory::{AccessCounter, EnergyTable, Level};
use swcnn::systolic::cluster::{BlockMatrix, Cluster};
use swcnn::util::Rng;

fn main() {
    let t = EnergyTable::default();
    let rows: Vec<Vec<String>> = t
        .figure6_rows()
        .iter()
        .map(|(n, e)| {
            let bar = "#".repeat(((e.log10() + 1.0) * 8.0).max(1.0) as usize);
            vec![n.to_string(), format!("{e:.1}x"), bar]
        })
        .collect();
    print_table(
        "Fig. 6: data movement energy vs hierarchy (log bar)",
        &["level", "energy", ""],
        &rows,
    );

    // Measured: a 32^3 matmul with FIFO sharing vs without (every block
    // refetched from local memory) — why the cluster FIFOs matter.
    let mut rng = Rng::new(4);
    let a = rng.gaussian_vec(32 * 32);
    let b = rng.gaussian_vec(32 * 32);
    let mut cl = Cluster::new(4);
    let stats = time_it(2, 10, || {
        let mut c2 = Cluster::new(4);
        std::hint::black_box(c2.matmul(
            &BlockMatrix::new(&a, 32, 32, 4),
            &BlockMatrix::new(&b, 32, 32, 4),
        ));
    });
    let _ = cl.matmul(
        &BlockMatrix::new(&a, 32, 32, 4),
        &BlockMatrix::new(&b, 32, 32, 4),
    );
    let words_per_block = 16u64;
    let mut shared = AccessCounter::default();
    shared.record(Level::Local, (cl.stats.a_fetches + cl.stats.b_fetches) * words_per_block);
    shared.record(Level::Fifo, cl.stats.fifo_reads * words_per_block);
    let mut unshared = AccessCounter::default();
    unshared.record(Level::Local, cl.stats.fifo_reads * words_per_block);
    println!(
        "\n32x32x32 matmul data-movement energy: shared FIFOs {:.0} units vs {:.0} without sharing ({:.2}x saved); sim {:.2} ms/run",
        shared.energy(&t),
        unshared.energy(&t),
        unshared.energy(&t) / shared.energy(&t),
        stats.mean * 1e3,
    );
}
