//! Bench/repro for Fig. 7(a): energy-consumption estimation of VGG16
//! Winograd convolution as a function of m (the analytical model of
//! §5.1.3 with the Fig. 6 energy table).
//!
//!   cargo bench --bench fig7a

use swcnn::bench::{print_table, time_it};
use swcnn::memory::EnergyTable;
use swcnn::model::energy_vs_m;
use swcnn::nn::vgg16_network;

fn main() {
    let net = vgg16_network();
    let table = EnergyTable::default();
    let stats = time_it(3, 20, || {
        std::hint::black_box(energy_vs_m(&net, &[2, 3, 4, 6], &table));
    });
    let curve = energy_vs_m(&net, &[2, 3, 4, 6], &table);
    let e_min = curve.iter().map(|&(_, e)| e).fold(f64::INFINITY, f64::min);
    let rows: Vec<Vec<String>> = curve
        .iter()
        .map(|&(m, e)| {
            let rel = e / e_min;
            vec![
                m.to_string(),
                format!("{e:.3e}"),
                format!("{rel:.3}"),
                "#".repeat((rel * 24.0) as usize),
            ]
        })
        .collect();
    print_table(
        "Fig. 7(a): VGG16 energy vs m (normalized to the minimum)",
        &["m", "energy (MAC units)", "rel", ""],
        &rows,
    );
    println!(
        "\npaper shape: small m consumes less energy; m=4 can edge out m=2\n\
         (the paper picked m=2 for hardware simplicity).  sweep: {:.1} ms",
        stats.mean * 1e3
    );
}
