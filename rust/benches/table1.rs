//! Bench/repro for Table 1: Winograd neuron & weight counts per VGG16
//! stage at m = 2, printed next to the paper's numbers.
//!
//!   cargo bench --bench table1

use swcnn::bench::{print_table, time_it};
use swcnn::model::table1;
use swcnn::nn::vgg16_network;

// Paper Table 1 rows: (label, neurons, weights).
const PAPER: &[(&str, u64, u64)] = &[
    ("Conv1 (x2)", 12_845_056, 65_536),
    ("Conv2 (x3)", 6_422_528, 262_144),
    ("Conv3 (x4)", 3_211_264, 1_048_576),
    ("Conv4 (x4)", 1_605_632, 4_194_304),
    ("Conv5 (x4)", 401_408, 4_194_304),
    ("Conv6", 131_072, 4_194_304),
];

fn main() {
    let net = vgg16_network();
    let stats = time_it(3, 20, || {
        std::hint::black_box(table1(&net, 2));
    });
    let rows = table1(&net, 2);

    let mut out = Vec::new();
    for &(label, pn, pw) in PAPER {
        // Find our row with the same weight volume & closest neuron count.
        let ours = rows
            .iter()
            .filter(|r| r.weights == pw)
            .min_by_key(|r| r.neurons.abs_diff(pn));
        let (on, ow) = ours.map(|r| (r.neurons, r.weights)).unwrap_or((0, 0));
        out.push(vec![
            label.to_string(),
            pn.to_string(),
            on.to_string(),
            pw.to_string(),
            ow.to_string(),
            if pn == on && pw == ow { "exact" } else { "≈" }.to_string(),
        ]);
    }
    print_table(
        "Table 1 reproduction (m=2)",
        &["stage", "paper neurons", "ours", "paper weights", "ours", "match"],
        &out,
    );
    println!("\nmodel evaluation: {:.1} µs/run (n={})", stats.mean * 1e6, stats.n);
}
