//! Serving-throughput bench: the batched native engine on sparse
//! vgg_tiny, sweeping fused batch sizes 1 / 2 / 4 / 8.
//!
//!   cargo bench --bench serving
//!
//! One `forward_batch` launch runs every layer's cached (sparse) filter
//! bank once for the whole batch — the batch-amortized weight reuse the
//! paper's 3-D cluster extension banks on.  The sweep is written to
//! `BENCH_serving.json` (in the bench working directory) so the
//! amortization shows up in the perf trajectory, and the bench asserts
//! the two gates that make the serving claim real rather than cosmetic:
//!
//! - **bit-identity**: every batched result equals the sequential
//!   per-image `forward` results exactly, for every batch size;
//! - **amortization**: batch-4 throughput (images/s) strictly above
//!   batch-1.

use swcnn::bench::{print_table, time_it};
use swcnn::executor::{ExecPolicy, Session};
use swcnn::nn::graph::Synthetic;
use swcnn::nn::vgg_tiny;
use swcnn::util::json::Json;
use swcnn::util::Rng;

const BATCHES: [usize; 4] = [1, 2, 4, 8];
const SPARSITY: f64 = 0.7;

fn main() {
    let max_batch = *BATCHES.iter().max().unwrap();
    let mut exec = Session::uniform(
        vgg_tiny(),
        &mut Synthetic::new(7),
        ExecPolicy::sparse(2, SPARSITY),
    )
    .expect("vgg_tiny compiles")
    .with_max_batch(max_batch);
    let mut rng = Rng::new(42);
    let images: Vec<Vec<f32>> = (0..max_batch)
        .map(|_| rng.gaussian_vec(exec.input_elements()))
        .collect();
    let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();

    // Correctness gate: a fast-but-wrong batched engine must fail the
    // bench.  Every batch size must reproduce the sequential per-image
    // logits bit for bit.
    let seq: Vec<Vec<f32>> = images
        .iter()
        .map(|im| exec.forward(im).expect("forward"))
        .collect();
    for &n in &BATCHES {
        let got = exec.forward_batch(&refs[..n]).expect("forward_batch");
        assert_eq!(
            got,
            seq[..n],
            "batch {n} must be bit-identical to sequential forward"
        );
    }

    let mut rows = Vec::new();
    let mut results = Vec::new();
    let mut per_batch_tput = Vec::new();
    for &n in &BATCHES {
        let s = time_it(1, 8, || {
            std::hint::black_box(exec.forward_batch(&refs[..n]).expect("forward_batch"));
        });
        let images_per_s = n as f64 / s.mean;
        per_batch_tput.push((n, images_per_s));
        results.push((n, s.mean, images_per_s));
        rows.push(vec![
            format!("forward_batch n={n}"),
            format!("{:.2} ms/launch", s.mean * 1e3),
            format!("{:.1} img/s", images_per_s),
        ]);
    }
    let tput = |want: usize| {
        per_batch_tput
            .iter()
            .find(|(n, _)| *n == want)
            .map(|(_, t)| *t)
            .unwrap()
    };
    let b1 = tput(1);
    let speedup4 = tput(4) / b1;
    let speedup8 = tput(8) / b1;
    rows.push(vec![
        "batch-4 vs batch-1".into(),
        format!("{speedup4:.2}x throughput"),
        "bit-identity verified for all batch sizes".into(),
    ]);
    print_table(
        &format!("serving throughput (sparse {SPARSITY} vgg_tiny, native engine)"),
        &["launch", "latency", "throughput"],
        &rows,
    );
    write_json(&results, speedup4, speedup8);

    // The amortization gate (CI runs this bench): sharing each stored
    // filter block across the batch must buy real throughput, not just
    // plumb a batch dimension through.
    assert!(
        speedup4 > 1.0,
        "batch-4 throughput must strictly beat batch-1 (got {speedup4:.2}x)"
    );
}

/// `BENCH_serving.json`: one row per fused batch size with per-launch
/// mean seconds and images/s, plus the headline batch-4 / batch-8
/// throughput ratios vs batch-1.
fn write_json(results: &[(usize, f64, f64)], speedup4: f64, speedup8: f64) {
    use std::collections::BTreeMap;
    let b1_tput = results
        .iter()
        .find(|(n, _, _)| *n == 1)
        .map(|(_, _, t)| *t)
        .unwrap();
    let rows: Vec<Json> = results
        .iter()
        .map(|&(n, mean_s, images_per_s)| {
            Json::Obj(BTreeMap::from([
                ("name".to_string(), Json::Str(format!("serve_vgg_tiny_b{n}"))),
                ("batch".to_string(), Json::Num(n as f64)),
                ("mean_s".to_string(), Json::Num(mean_s)),
                ("images_per_s".to_string(), Json::Num(images_per_s)),
                (
                    "speedup_vs_b1".to_string(),
                    Json::Num(images_per_s / b1_tput),
                ),
            ]))
        })
        .collect();
    let top = BTreeMap::from([
        ("bench".to_string(), Json::Str("serving".to_string())),
        ("schema".to_string(), Json::Num(1.0)),
        ("network".to_string(), Json::Str("vgg_tiny".to_string())),
        (
            "policy".to_string(),
            Json::Str(format!("sparse F(2,3) p={SPARSITY}")),
        ),
        ("results".to_string(), Json::Arr(rows)),
        ("batch4_speedup_vs_b1".to_string(), Json::Num(speedup4)),
        ("batch8_speedup_vs_b1".to_string(), Json::Num(speedup8)),
    ]);
    let path = "BENCH_serving.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
