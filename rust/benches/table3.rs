//! Bench/repro for Table 3: resource usage of the paper configuration
//! under the calibrated cost model, vs the paper's synthesis numbers.
//!
//!   cargo bench --bench table3

use swcnn::bench::{print_table, time_it};
use swcnn::resources::{estimate, paper_configuration, CostModel, XCVU095};

fn main() {
    let stats = time_it(10, 100, || {
        std::hint::black_box(paper_configuration());
    });
    let u = paper_configuration();
    let (lu, fu, bu, du) = u.utilization(&XCVU095);

    let rows = vec![
        vec![
            "LUTs".into(),
            "241,202".into(),
            u.luts.to_string(),
            XCVU095.luts.to_string(),
            format!("{:.1}%", lu * 100.0),
        ],
        vec![
            "FF".into(),
            "634,136".into(),
            u.ffs.to_string(),
            XCVU095.ffs.to_string(),
            format!("{:.1}%", fu * 100.0),
        ],
        vec![
            "BRAM".into(),
            "1,480".into(),
            u.brams.to_string(),
            XCVU095.brams.to_string(),
            format!("{:.1}%", bu * 100.0),
        ],
        vec![
            "DSP".into(),
            "512 + 256".into(),
            format!("{} + {}", u.dsp_arith, u.dsp_transform),
            XCVU095.dsps.to_string(),
            format!("{:.0}%", du * 100.0),
        ],
    ];
    print_table(
        "Table 3 reproduction (XCVU095)",
        &["resource", "paper", "ours (model)", "available", "pct"],
        &rows,
    );

    // Ablation: dense-only design drops the decompressors.
    let dense = estimate(&CostModel::default(), 4, 8, 16, false);
    println!(
        "\nablation: removing sparse decompressors saves {} LUTs / {} FFs",
        u.luts - dense.luts,
        u.ffs - dense.ffs
    );
    println!("cost-model evaluation: {:.2} µs/run", stats.mean * 1e6);
}
