//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! A1. Z-Morton vs row-major block layout — FIFO hit rate / fetch count.
//! A2. Pipelined (Fig. 1) vs sequential 3-stage layer execution.
//! A3. Streaming vs unpipelined Winograd transform arrays.
//! A4. Shared cluster FIFOs vs private (no sharing) — memory energy.
//! A5. Naive vs LPT wave scheduling of the l^2 sparse coordinate matmuls.
//! A6. Winograd vs direct (im2col) convolution on the same clusters.
//!
//!   cargo bench --bench ablations

use swcnn::bench::print_table;
use swcnn::memory::EnergyTable;
use swcnn::nn::vgg16_network;
use swcnn::scheduler::{
    schedule_dense, schedule_direct, schedule_sparse, schedule_waves,
    AcceleratorConfig, WavePolicy,
};
use swcnn::sparse::{synthetic_sparse_matrix, Bcoo};
use swcnn::systolic::cluster::{BlockMatrix, Cluster};
use swcnn::systolic::BlockTiming;
use swcnn::util::Rng;
use swcnn::zmorton;

fn main() {
    let mut rows = Vec::new();
    let mut rng = Rng::new(2024);
    let cfg = AcceleratorConfig::paper();
    let conv5 = vgg16_network().convs[10].shape();

    // A1: Z-Morton locality.  Replay the unrolled Algorithm-1 schedule's
    // operand-block streams through a small circular FIFO (capacity 8
    // blocks — the on-chip budget) and compare hit rates against the
    // naive row-major i-j-k loop order over the same block grid.
    {
        use swcnn::systolic::CircularFifo;
        let n = 16usize;
        let replay = |pairs: &[(u64, u64)]| {
            let mut fifo = CircularFifo::new(8);
            for &(a, b) in pairs {
                let _ = fifo.read_block(a << 32, Vec::new);
                let _ = fifo.read_block(b << 32 | 1, Vec::new);
            }
            fifo.hits as f64 / fifo.reads as f64
        };
        let z: Vec<(u64, u64)> = zmorton::schedule(n)
            .iter()
            .map(|s| (s.a_block, s.b_block))
            .collect();
        let mut rowmajor = Vec::new();
        for i in 0..n as u32 {
            for j in 0..n as u32 {
                for k in 0..n as u32 {
                    rowmajor.push((zmorton::encode(i, k), zmorton::encode(k, j)));
                }
            }
        }
        let (hz, hrm) = (replay(&z), replay(&rowmajor));
        rows.push(vec![
            "A1 FIFO(8) hit rate (16^3 blocks)".into(),
            format!("z-morton {:.1}%", hz * 100.0),
            format!("row-major {:.1}%", hrm * 100.0),
            format!("{:+.1} pp", (hz - hrm) * 100.0),
        ]);
    }

    // A2: pipelined vs sequential stages on conv5_1.
    {
        let plan = schedule_dense(&conv5, &cfg);
        rows.push(vec![
            "A2 conv5_1 stage pipeline".into(),
            format!("pipelined {}", plan.pipelined_cycles()),
            format!("sequential {}", plan.sequential_cycles()),
            format!(
                "{:.2}x",
                plan.sequential_cycles() as f64 / plan.pipelined_cycles() as f64
            ),
        ]);
    }

    // A3: streaming vs unpipelined transform.
    {
        let t = BlockTiming::new(4);
        let tiles = 112 * 112 * 64u64; // conv1_2 input tiles
        let streaming = t.transform_cycles(tiles / 16, 2);
        let unpip = t.transform_cycles_unpipelined(tiles / 16);
        rows.push(vec![
            "A3 transform 802k tiles".into(),
            format!("streaming {streaming}"),
            format!("unpipelined {unpip}"),
            format!("{:.2}x", unpip as f64 / streaming as f64),
        ]);
    }

    // A4: shared FIFOs vs private — measured fetches on a 32^3 matmul.
    {
        let a = rng.gaussian_vec(32 * 32);
        let b = rng.gaussian_vec(32 * 32);
        let mut cl = Cluster::new(4);
        let _ = cl.matmul(
            &BlockMatrix::new(&a, 32, 32, 4),
            &BlockMatrix::new(&b, 32, 32, 4),
        );
        let fetches_shared = cl.stats.a_fetches + cl.stats.b_fetches;
        let fetches_private = cl.stats.fifo_reads; // every read would fetch
        let t = EnergyTable::default();
        let e_shared = fetches_shared as f64 * 16.0 * t.e_local;
        let e_private = fetches_private as f64 * 16.0 * t.e_local;
        rows.push(vec![
            "A4 32^3 operand fetches".into(),
            format!("shared {fetches_shared} ({e_shared:.0} eu)"),
            format!("private {fetches_private} ({e_private:.0} eu)"),
            format!("{:.2}x", fetches_private as f64 / fetches_shared as f64),
        ]);
    }

    // A5: naive vs LPT waves for sparse coordinate matmuls (conv5_1, 90%).
    {
        let l = cfg.l();
        let t = BlockTiming::new(l);
        let per: Vec<u64> = (0..l * l)
            .map(|_| {
                let mat =
                    synthetic_sparse_matrix(&mut rng, conv5.in_ch, conv5.out_ch, l, 0.9);
                let bcoo = Bcoo::compress(&mat, conv5.in_ch, conv5.out_ch, l);
                t.sparse_matmul_cycles(49, &bcoo)
            })
            .collect();
        let naive = schedule_waves(&per, cfg.clusters, WavePolicy::Naive);
        let lpt = schedule_waves(&per, cfg.clusters, WavePolicy::Lpt);
        rows.push(vec![
            "A5 sparse90 wave makespan".into(),
            format!("naive {naive}"),
            format!("LPT {lpt}"),
            format!("{:.3}x", naive as f64 / lpt as f64),
        ]);
    }

    // A6: Winograd vs direct convolution cycles (conv5_1).
    {
        let wino = schedule_dense(&conv5, &cfg).matmul_cycles;
        let direct = schedule_direct(&conv5, &cfg).matmul_cycles;
        rows.push(vec![
            "A6 conv5_1 matmul cycles".into(),
            format!("winograd {wino}"),
            format!("direct {direct}"),
            format!("{:.2}x (theory 2.25x)", direct as f64 / wino as f64),
        ]);
    }

    // A7: sparse-schedule occupancy across sparsities (skip effectiveness).
    for p in [0.6, 0.9] {
        let l = cfg.l();
        let mats: Vec<Vec<f32>> = (0..l * l)
            .map(|_| synthetic_sparse_matrix(&mut rng, conv5.in_ch, conv5.out_ch, l, p))
            .collect();
        let bcoos: Vec<Bcoo> = mats
            .iter()
            .map(|m| Bcoo::compress(m, conv5.in_ch, conv5.out_ch, l))
            .collect();
        let dirs: Vec<Option<&Bcoo>> = bcoos.iter().map(Some).collect();
        let plan = schedule_sparse(&conv5, &cfg, &dirs);
        rows.push(vec![
            format!("A7 occupancy @{:.0}%", p * 100.0),
            format!("{:.3}", plan.occupancy),
            format!("expected {:.3}", 1.0 - p * p),
            String::new(),
        ]);
    }

    print_table("ablations", &["ablation", "ours", "baseline", "delta"], &rows);
}
