//! Hot-path microbenchmarks for the §Perf optimization loop:
//! the detailed PE simulation, the closed-form timing model, Z-Morton
//! transforms, BCOO compression, and (when artifacts exist) PJRT
//! execution latency for the per-layer and end-to-end executables.
//!
//!   cargo bench --bench hotpath

use swcnn::bench::{print_table, time_it};
use swcnn::sparse::{synthetic_sparse_matrix, Bcoo};
use swcnn::systolic::cluster::{BlockMatrix, Cluster};
use swcnn::systolic::BlockTiming;
use swcnn::util::{eng, Rng};
use swcnn::zmorton;

fn main() {
    let mut rows = Vec::new();
    let mut rng = Rng::new(1);

    // Detailed cluster simulation, 64^3 dense.
    let a = rng.gaussian_vec(64 * 64);
    let b = rng.gaussian_vec(64 * 64);
    let s = time_it(2, 10, || {
        let mut cl = Cluster::new(4);
        std::hint::black_box(cl.matmul(
            &BlockMatrix::new(&a, 64, 64, 4),
            &BlockMatrix::new(&b, 64, 64, 4),
        ));
    });
    let macs = BlockTiming::new(4).dense_macs(64, 64, 64) as f64;
    rows.push(vec![
        "cluster sim 64^3 dense".into(),
        format!("{:.3} ms", s.mean * 1e3),
        format!("{} MAC/s simulated", eng(macs / s.mean)),
    ]);

    // Sparse cluster simulation at 90%.
    let bs = synthetic_sparse_matrix(&mut rng, 64, 64, 4, 0.9);
    let bcoo = Bcoo::compress(&bs, 64, 64, 4);
    let s = time_it(2, 10, || {
        let mut cl = Cluster::new(4);
        std::hint::black_box(cl.matmul_sparse(&BlockMatrix::new(&a, 64, 64, 4), &bcoo));
    });
    rows.push(vec![
        "cluster sim 64^3 sparse90".into(),
        format!("{:.3} ms", s.mean * 1e3),
        String::new(),
    ]);

    // Closed-form timing model (the sweep hot path).
    let t = BlockTiming::new(4);
    let s = time_it(10, 50, || {
        std::hint::black_box(t.sparse_matmul_cycles(512, &bcoo));
    });
    rows.push(vec![
        "timing model sparse walk".into(),
        format!("{:.1} µs", s.mean * 1e6),
        String::new(),
    ]);

    // Z-Morton encode/decode throughput.
    let s = time_it(2, 20, || {
        let mut acc = 0u64;
        for i in 0..1_000_000u32 {
            acc = acc.wrapping_add(zmorton::encode(i, i ^ 0xAAAA));
        }
        std::hint::black_box(acc);
    });
    rows.push(vec![
        "zmorton encode x1e6".into(),
        format!("{:.2} ms", s.mean * 1e3),
        format!("{} enc/s", eng(1e6 / s.mean)),
    ]);

    // BCOO compression of a VGG-scale weight matrix.
    let big = synthetic_sparse_matrix(&mut rng, 512, 512, 4, 0.8);
    let s = time_it(2, 10, || {
        std::hint::black_box(Bcoo::compress(&big, 512, 512, 4));
    });
    rows.push(vec![
        "BCOO compress 512x512".into(),
        format!("{:.2} ms", s.mean * 1e3),
        String::new(),
    ]);

    // PJRT execution latency (needs artifacts).
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use swcnn::runtime::Runtime;
        let mut rt = Runtime::new("artifacts").expect("runtime");
        for name in ["quickstart", "vgg_tiny_b1", "vgg_tiny_b4", "vgg16_conv5"] {
            let model = rt.load(name).expect(name);
            let n_in: usize = model
                .spec
                .request_inputs()
                .next()
                .map(|i| i.elements())
                .unwrap_or(0);
            let x = Rng::new(7).gaussian_vec(n_in);
            let s = time_it(3, 20, || {
                std::hint::black_box(model.run(&[x.clone()]).expect("run"));
            });
            let per_img = match name {
                "vgg_tiny_b4" => s.mean / 4.0,
                _ => s.mean,
            };
            rows.push(vec![
                format!("pjrt {name}"),
                format!("{:.3} ms", s.mean * 1e3),
                format!("{:.3} ms/img", per_img * 1e3),
            ]);
        }
    } else {
        rows.push(vec![
            "pjrt artifacts".into(),
            "skipped".into(),
            "run `make artifacts`".into(),
        ]);
    }

    print_table("hot paths", &["path", "time", "notes"], &rows);
}
