//! Hot-path microbenchmarks for the §Perf optimization loop:
//! the precomputed-plan Winograd engine vs the seed per-tile oracle, the
//! detailed PE simulation, the closed-form timing model, Z-Morton
//! transforms, BCOO compression, and (when artifacts exist) PJRT
//! execution latency for the per-layer and end-to-end executables.
//!
//!   cargo bench --bench hotpath
//!
//! Besides the human-readable table, every measurement is written to
//! `BENCH_hotpath.json` (in the bench working directory) so the perf
//! trajectory is machine-trackable across PRs.  The sparse-vs-dense
//! sweep (block sparsity 0 / 0.5 / 0.7 / 0.9 on the VGG-ish layer) is
//! additionally written to `BENCH_sparse.json` with bit-identity gates
//! (sparsity 0.0 == dense plan; every row == dense run of the
//! decompressed pruned weights).

use swcnn::bench::{print_table, time_it};
use swcnn::executor::{ConvExecutor, ExecPolicy, Session};
use swcnn::nn::graph::{Synthetic, WeightSource};
use swcnn::nn::{self, vgg_tiny};
use swcnn::sparse::{synthetic_sparse_matrix, Bcoo};
use swcnn::systolic::cluster::{BlockMatrix, Cluster};
use swcnn::systolic::BlockTiming;
use swcnn::tensor::Tensor;
use swcnn::tuner::{TuneProfile, Tuner};
use swcnn::util::json::Json;
use swcnn::util::{eng, Rng, Stats};
use swcnn::winograd::{direct_conv2d, simd, winograd_conv2d_reference, VectorWidth, WinogradPlan};

/// One recorded measurement: (name, stats, human note).
struct Record {
    name: String,
    stats: Stats,
    note: String,
}

fn record(records: &mut Vec<Record>, name: &str, stats: Stats, note: String) {
    records.push(Record {
        name: name.to_string(),
        stats,
        note,
    });
}

fn write_json(records: &[Record], extras: &[(String, f64)]) {
    use std::collections::BTreeMap;
    let results: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::Obj(BTreeMap::from([
                ("name".to_string(), Json::Str(r.name.clone())),
                ("mean_s".to_string(), Json::Num(r.stats.mean)),
                ("median_s".to_string(), Json::Num(r.stats.median)),
                ("min_s".to_string(), Json::Num(r.stats.min)),
                ("max_s".to_string(), Json::Num(r.stats.max)),
                ("std_dev_s".to_string(), Json::Num(r.stats.std_dev)),
                ("iters".to_string(), Json::Num(r.stats.n as f64)),
                ("note".to_string(), Json::Str(r.note.clone())),
            ]))
        })
        .collect();
    let mut top = BTreeMap::from([
        ("bench".to_string(), Json::Str("hotpath".to_string())),
        ("schema".to_string(), Json::Num(1.0)),
        ("results".to_string(), Json::Arr(results)),
    ]);
    for (k, v) in extras {
        top.insert(k.clone(), Json::Num(*v));
    }
    let path = "BENCH_hotpath.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// The sparse-vs-dense sweep gate: one row per block sparsity on the
/// VGG-ish layer, plus the headline ratios, in machine-readable form.
fn write_sparse_json(
    sweep: &[(f64, f64, f64)],
    dense_mean_s: f64,
    speedup_at_09: f64,
    overhead_at_00: f64,
) {
    use std::collections::BTreeMap;
    let results: Vec<Json> = sweep
        .iter()
        .map(|&(target, measured, mean_s)| {
            Json::Obj(BTreeMap::from([
                (
                    "name".to_string(),
                    Json::Str(format!(
                        "wino_sparse{:02}_f43_c64k64_56",
                        (target * 100.0).round() as u32
                    )),
                ),
                ("target_sparsity".to_string(), Json::Num(target)),
                ("block_sparsity".to_string(), Json::Num(measured)),
                ("mean_s".to_string(), Json::Num(mean_s)),
                (
                    "speedup_vs_dense".to_string(),
                    Json::Num(dense_mean_s / mean_s),
                ),
            ]))
        })
        .collect();
    let top = BTreeMap::from([
        ("bench".to_string(), Json::Str("sparse".to_string())),
        ("schema".to_string(), Json::Num(1.0)),
        ("layer".to_string(), Json::Str("f43_c64k64_56".to_string())),
        ("dense_mean_s".to_string(), Json::Num(dense_mean_s)),
        ("results".to_string(), Json::Arr(results)),
        ("sparse_speedup_at_0_9".to_string(), Json::Num(speedup_at_09)),
        (
            "sparse_overhead_at_0_0".to_string(),
            Json::Num(overhead_at_00),
        ),
    ]);
    let path = "BENCH_sparse.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// `BENCH_tuner.json`: one row per vgg_tiny layer with the tuned choice
/// and the measured tuned-vs-default ratio, plus the whole-network
/// speedup and the profile's fused batch pick.  The CI regression gate
/// compares the `ratio_vs_default` / `*speedup*` fields against the
/// committed baselines.
fn write_tuner_json(
    profile: &TuneProfile,
    layer_rows: &[(String, String, f64, f64)],
    net_speedup: f64,
) {
    use std::collections::BTreeMap;
    let results: Vec<Json> = layer_rows
        .iter()
        .map(|(name, choice, default_s, tuned_s)| {
            Json::Obj(BTreeMap::from([
                ("name".to_string(), Json::Str(name.clone())),
                ("choice".to_string(), Json::Str(choice.clone())),
                ("default_median_s".to_string(), Json::Num(*default_s)),
                ("tuned_median_s".to_string(), Json::Num(*tuned_s)),
                (
                    "ratio_vs_default".to_string(),
                    Json::Num(default_s / tuned_s),
                ),
            ]))
        })
        .collect();
    let top = BTreeMap::from([
        ("bench".to_string(), Json::Str("tuner".to_string())),
        ("schema".to_string(), Json::Num(1.0)),
        ("network".to_string(), Json::Str(profile.network.clone())),
        ("batch".to_string(), Json::Num(profile.batch as f64)),
        (
            "tuned_net_speedup_vs_default".to_string(),
            Json::Num(net_speedup),
        ),
        ("results".to_string(), Json::Arr(results)),
    ]);
    let path = "BENCH_tuner.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let mut rows = Vec::new();
    let mut records = Vec::new();
    let mut extras = Vec::new();
    let mut rng = Rng::new(1);

    // ------------------------------------------------------------------
    // Plan engine vs the seed per-tile oracle: a VGG-sized layer,
    // C=64, K=64, 56x56 input, F(4,3).  The oracle regenerates the
    // rational transform matrices per tile/channel and allocates per
    // iteration; the plan caches both — this gap is the PR's headline.
    // ------------------------------------------------------------------
    let (c, k, hw, m) = (64usize, 64usize, 56usize, 4usize);
    let x = Tensor::from_vec(&[c, hw, hw], rng.gaussian_vec(c * hw * hw));
    let w = Tensor::from_vec(&[k, c, 3, 3], rng.gaussian_vec(k * c * 9));

    let s_naive = time_it(0, 2, || {
        std::hint::black_box(winograd_conv2d_reference(&x, &w, m));
    });
    record(
        &mut records,
        "wino_naive_f43_c64k64_56",
        s_naive,
        "seed per-tile oracle".into(),
    );
    rows.push(vec![
        "winograd naive F(4,3) 64c/64k 56²".into(),
        format!("{:.1} ms", s_naive.mean * 1e3),
        "regenerates transforms per tile".into(),
    ]);

    let mut plan1 = WinogradPlan::new(m, 3).with_threads(1);
    let s_plan1 = time_it(1, 5, || {
        std::hint::black_box(plan1.conv2d(&x, &w));
    });
    record(
        &mut records,
        "wino_plan_1thread_f43_c64k64_56",
        s_plan1,
        "plan engine, single worker".into(),
    );
    rows.push(vec![
        "winograd plan (1 thread)".into(),
        format!("{:.2} ms", s_plan1.mean * 1e3),
        format!("{:.1}x vs naive", s_naive.mean / s_plan1.mean),
    ]);

    let mut plan = WinogradPlan::new(m, 3);
    let s_plan = time_it(1, 5, || {
        std::hint::black_box(plan.conv2d(&x, &w));
    });
    record(
        &mut records,
        "wino_plan_f43_c64k64_56",
        s_plan,
        format!("plan engine, {} workers", plan.threads()),
    );
    rows.push(vec![
        format!("winograd plan ({} threads)", plan.threads()),
        format!("{:.2} ms", s_plan.mean * 1e3),
        format!("{:.1}x vs naive", s_naive.mean / s_plan.mean),
    ]);

    let bank = plan.transform_filters(&w);
    let s_bank = time_it(1, 5, || {
        std::hint::black_box(plan.conv2d_with_filters(&x, &bank));
    });
    record(
        &mut records,
        "wino_plan_bank_f43_c64k64_56",
        s_bank,
        "pre-transformed filter bank (serving steady state)".into(),
    );
    rows.push(vec![
        "winograd plan + filter bank".into(),
        format!("{:.2} ms", s_bank.mean * 1e3),
        format!("{:.1}x vs naive", s_naive.mean / s_bank.mean),
    ]);

    // Correctness gate: a fast-but-wrong engine must fail the bench.
    let got = plan.conv2d(&x, &w);
    let want = direct_conv2d(&x, &w);
    assert!(
        got.allclose(&want, 1e-4, 1e-4),
        "plan engine disagrees with direct conv: max diff {}",
        got.max_abs_diff(&want)
    );
    let speedup = s_naive.mean / s_plan.mean;
    extras.push(("plan_speedup_vs_naive".into(), speedup));
    rows.push(vec![
        "plan vs naive speedup".into(),
        format!("{speedup:.1}x"),
        "allclose(direct, rtol 1e-4) verified".into(),
    ]);

    // ------------------------------------------------------------------
    // Sparse transform-domain sweep on the same VGG-ish layer: block
    // sparsity 0 / 0.5 / 0.7 / 0.9 through `conv2d_sparse_with_filters`,
    // against the dense filter-bank baseline measured above.  Emits
    // BENCH_sparse.json (the acceptance gate of the sparse pipeline PR).
    // ------------------------------------------------------------------
    let mut sparse_rows: Vec<(f64, f64, f64)> = Vec::new(); // (target, measured, mean_s)
    for sp in [0.0f64, 0.5, 0.7, 0.9] {
        let sbank = plan.transform_filters_sparse(&w, sp);
        let s_sp = time_it(1, 5, || {
            std::hint::black_box(plan.conv2d_sparse_with_filters(&x, &sbank));
        });
        record(
            &mut records,
            &format!("wino_sparse{:02}_f43_c64k64_56", (sp * 100.0).round() as u32),
            s_sp,
            format!("sparse plan, block sparsity {sp:.1}"),
        );
        // Correctness gates: 0.0 must be bit-identical to the dense plan;
        // every sparsity must equal a dense run of the decompressed
        // pruned weights bit-for-bit.
        let ys = plan.conv2d_sparse_with_filters(&x, &sbank);
        if sp == 0.0 {
            let yd = plan.conv2d_with_filters(&x, &bank);
            assert_eq!(ys, yd, "sparsity 0.0 must be bit-identical to dense");
        }
        let yp = plan.conv2d_with_filters(&x, &sbank.to_dense_bank());
        assert_eq!(ys, yp, "sparse vs decompressed-dense at {sp}");
        sparse_rows.push((sp, sbank.block_sparsity(), s_sp.mean));
        rows.push(vec![
            format!("winograd sparse p={sp:.1}"),
            format!("{:.2} ms", s_sp.mean * 1e3),
            format!("{:.2}x vs dense bank", s_bank.mean / s_sp.mean),
        ]);
    }
    let sparse90_speedup = s_bank.mean / sparse_rows[3].2;
    let sparse0_overhead = sparse_rows[0].2 / s_bank.mean;
    extras.push(("sparse_speedup_at_0_9".into(), sparse90_speedup));
    extras.push(("sparse_overhead_at_0_0".into(), sparse0_overhead));
    write_sparse_json(&sparse_rows, s_bank.mean, sparse90_speedup, sparse0_overhead);
    // Regression gates (slightly looser than the PR acceptance targets of
    // 2x / 1.10x to absorb shared-runner noise, but tight enough that a
    // real sparse-path regression fails the bench):
    assert!(
        sparse90_speedup >= 1.5,
        "sparse at 0.9 must beat the dense bank (got {sparse90_speedup:.2}x, want >= 2x)"
    );
    assert!(
        sparse0_overhead <= 1.35,
        "sparse at 0.0 overhead {sparse0_overhead:.2}x vs dense (want within 10%)"
    );

    // ------------------------------------------------------------------
    // Batched sparse launch on the same layer: one fused batch-4 call
    // decodes every stored weight block once and streams it against all
    // four images' tiles (the serving path's amortization).  Gated on
    // per-image bit-identity with the single-image engine.
    // ------------------------------------------------------------------
    {
        let sbank = plan.transform_filters_sparse(&w, 0.7);
        let single_mean = sparse_rows
            .iter()
            .find(|row| row.0 == 0.7)
            .expect("0.7 row in the sparsity sweep")
            .2;
        let n = 4usize;
        let xb = Tensor::from_vec(&[n, c, hw, hw], rng.gaussian_vec(n * c * hw * hw));
        let s_b4 = time_it(1, 5, || {
            std::hint::black_box(plan.conv2d_sparse_with_filters_batch(&xb, &sbank));
        });
        let yb = plan.conv2d_sparse_with_filters_batch(&xb, &sbank);
        let per = yb.len() / n;
        for i in 0..n {
            let xi = Tensor::from_vec(
                &[c, hw, hw],
                xb.data()[i * c * hw * hw..(i + 1) * c * hw * hw].to_vec(),
            );
            let want = plan.conv2d_sparse_with_filters(&xi, &sbank);
            assert_eq!(
                &yb.data()[i * per..(i + 1) * per],
                want.data(),
                "batched image {i} must be bit-identical to the single-image engine"
            );
        }
        let per_image_speedup = single_mean / (s_b4.mean / n as f64);
        record(
            &mut records,
            "wino_sparse70_batch4_f43_c64k64_56",
            s_b4,
            format!("fused batch-4 launch, {per_image_speedup:.2}x per image vs batch-1"),
        );
        extras.push(("sparse_batch4_per_image_speedup".into(), per_image_speedup));
        rows.push(vec![
            "winograd sparse p=0.7 batch-4".into(),
            format!("{:.2} ms/launch", s_b4.mean * 1e3),
            format!("{per_image_speedup:.2}x per image vs batch-1"),
        ]);
    }

    // ------------------------------------------------------------------
    // Per-layer autotuner: tuned-vs-default on every vgg_tiny layer.
    // The tuner picks (m, workers, backend) per layer from the §5.1
    // analytical model refined by its bounded calibration pass; the
    // bench then re-measures both configurations per layer and the
    // whole-network forward, and emits BENCH_tuner.json — the input of
    // the CI bench-regression gate.  Layers where the tuner keeps the
    // default configuration share one measurement (ratio exactly 1.0);
    // layers where it deviates must hold the measured win.
    // ------------------------------------------------------------------
    {
        let base = ExecPolicy::sparse(2, 0.7);
        let seed = 7u64;
        let profile = Tuner::new(vgg_tiny(), base, seed).tune().expect("tune");
        // The conv weights exactly as a seeded session binds them: the
        // canonical request order is convs-first, so pulling the conv
        // specs in order reproduces the serving stream.
        let mut src = Synthetic::new(seed);
        let weights: Vec<Tensor> = vgg_tiny()
            .weight_requests()
            .iter()
            .filter(|spec| spec.shape.len() == 4)
            .map(|spec| src.tensor(spec).expect("synthetic weights"))
            .collect();
        let convs = vgg_tiny().conv_infos();
        let default_workers = WinogradPlan::default_threads();
        let tuned_policies = profile
            .policies_for(&vgg_tiny(), &base)
            .expect("fresh profile matches its own graph");
        let mut layer_rows: Vec<(String, String, f64, f64)> = Vec::new();
        let mut any_deviation = false;
        for (i, info) in convs.iter().enumerate() {
            let lt = &profile.layers[i];
            // ExecPolicy::for_conv is the executor's own small-channel
            // guard, so the measured configs are exactly what serving
            // builds.
            let default_policy = base.for_conv(&info.shape);
            let default_sparse = default_policy.wants_sparse();
            let tuned_policy = tuned_policies[i].for_conv(&info.shape);
            let p = nn::same_pad(info.shape.r);
            let (hp, wp) = (info.shape.hw + 2 * p, info.shape.hw + 2 * p);
            let xin = Tensor::from_vec(
                &[info.shape.in_ch, hp, wp],
                Rng::new(seed + i as u64).gaussian_vec(info.shape.in_ch * hp * wp),
            );
            let measure = |policy: &ExecPolicy| {
                let mut ex = ConvExecutor::prepare(&weights[i], policy).expect("prepare");
                time_it(1, 7, || {
                    std::hint::black_box(ex.conv2d(&xin));
                })
            };
            let s_default = measure(&default_policy);
            let same_config = lt.m == base.m
                && lt.workers == default_workers
                && lt.sparse == default_sparse;
            any_deviation |= !same_config;
            let s_tuned = if same_config { s_default } else { measure(&tuned_policy) };
            let ratio = s_default.median / s_tuned.median;
            let choice = format!(
                "F({},3) w={} {}",
                lt.m,
                lt.workers,
                if lt.sparse { "sparse" } else { "dense" }
            );
            rows.push(vec![
                format!("tuner {}: {choice}", info.name),
                format!(
                    "{:.3} ms vs {:.3} ms default",
                    s_tuned.median * 1e3,
                    s_default.median * 1e3
                ),
                format!("{ratio:.2}x vs default"),
            ]);
            layer_rows.push((
                info.name.clone(),
                choice,
                s_default.median,
                s_tuned.median,
            ));
            // Noise guard, not the acceptance bar: deviating layers were
            // chosen with a >= 5% calibrated win, so a re-measure landing
            // under 0.90 means a real problem rather than shared-runner
            // jitter (same-config layers share one measurement: 1.0).
            assert!(
                ratio >= 0.90,
                "{}: tuned config {:.3} ms regressed vs default {:.3} ms",
                info.name,
                s_tuned.median * 1e3,
                s_default.median * 1e3
            );
        }
        // Whole-network forward: the tuned profile vs the uniform default.
        let mut default_net =
            Session::uniform(vgg_tiny(), &mut Synthetic::new(seed), base).expect("session");
        let mut tuned_net =
            Session::build(vgg_tiny(), &mut Synthetic::new(seed), &tuned_policies)
                .expect("tuned session");
        let image = Rng::new(seed).gaussian_vec(default_net.input_elements());
        let s_dnet = time_it(1, 7, || {
            std::hint::black_box(default_net.forward(&image).expect("forward"));
        });
        let s_tnet = time_it(1, 7, || {
            std::hint::black_box(tuned_net.forward(&image).expect("forward"));
        });
        let net_speedup = s_dnet.median / s_tnet.median;
        rows.push(vec![
            "tuner vgg_tiny end-to-end".into(),
            format!(
                "{:.2} ms vs {:.2} ms default",
                s_tnet.median * 1e3,
                s_dnet.median * 1e3
            ),
            format!("{net_speedup:.2}x, fused batch {}", profile.batch),
        ]);
        write_tuner_json(&profile, &layer_rows, net_speedup);
        assert!(
            net_speedup >= 0.90,
            "tuned network forward regressed: {net_speedup:.2}x vs default"
        );
        // The acceptance headline — a strict per-layer win — only makes
        // sense when the tuner actually deviated somewhere; keeping the
        // default everywhere is a legitimate hysteresis outcome on
        // hardware where no candidate clears the margin, and must not
        // fail the bench.
        if any_deviation {
            let best = layer_rows
                .iter()
                .map(|(_, _, d, t)| d / t)
                .fold(f64::MIN, f64::max);
            assert!(
                best > 1.0,
                "tuner deviated from the default but never beat it \
                 (best ratio {best:.3})"
            );
        } else {
            println!(
                "tuner kept the default configuration on every layer \
                 (no candidate cleared the calibration hysteresis)"
            );
        }
    }

    // ------------------------------------------------------------------
    // SIMD hot loops: forced-scalar vs the widest supported vector width
    // on every vgg_tiny conv layer, dense and sparse.  The vector kernels
    // are bit-identical to scalar by construction (same operation order,
    // no FMA), so each pair is gated on `==` before it is timed; the
    // speedup ratios land in BENCH_hotpath.json for the CI regression
    // gate.  Acceptance bar: no layer may regress under the vector path,
    // and with an 8-lane ISA present at least one layer must clear 1.5x.
    // ------------------------------------------------------------------
    {
        let widest = simd::widest_supported();
        println!(
            "\nsimd: {} -> widest width {}{}",
            simd::detected_features(),
            widest,
            if simd::force_scalar() {
                " (SWCNN_FORCE_SCALAR set)"
            } else {
                ""
            }
        );
        if widest == VectorWidth::Scalar || simd::force_scalar() {
            rows.push(vec![
                "simd scalar-vs-vector".into(),
                "skipped".into(),
                "no vector width available on this host".into(),
            ]);
        } else {
            let seed = 7u64;
            let mut src = Synthetic::new(seed);
            let weights: Vec<Tensor> = vgg_tiny()
                .weight_requests()
                .iter()
                .filter(|spec| spec.shape.len() == 4)
                .map(|spec| src.tensor(spec).expect("synthetic weights"))
                .collect();
            let convs = vgg_tiny().conv_infos();
            let mut best = (f64::MIN, String::new());
            for (i, info) in convs.iter().enumerate() {
                let p = nn::same_pad(info.shape.r);
                let (hp, wp) = (info.shape.hw + 2 * p, info.shape.hw + 2 * p);
                let xin = Tensor::from_vec(
                    &[info.shape.in_ch, hp, wp],
                    Rng::new(seed + i as u64).gaussian_vec(info.shape.in_ch * hp * wp),
                );
                for (backend, base) in [
                    ("dense", ExecPolicy::dense(4)),
                    ("sparse", ExecPolicy::sparse(4, 0.7)),
                ] {
                    let policy = base.for_conv(&info.shape);
                    if backend == "sparse" && !policy.wants_sparse() {
                        // conv0's 3 input channels sit under the
                        // small-channel guard: no sparse row to measure.
                        continue;
                    }
                    let prepare = |vw: VectorWidth| {
                        ConvExecutor::prepare(&weights[i], &policy.with_vwidth(vw))
                            .expect("prepare")
                    };
                    let mut ex_s = prepare(VectorWidth::Scalar);
                    let mut ex_v = prepare(widest);
                    assert_eq!(
                        ex_v.conv2d(&xin),
                        ex_s.conv2d(&xin),
                        "{} {backend}: width {widest} must be bit-identical to scalar",
                        info.name
                    );
                    let s_scalar = time_it(2, 9, || {
                        std::hint::black_box(ex_s.conv2d(&xin));
                    });
                    let s_vec = time_it(2, 9, || {
                        std::hint::black_box(ex_v.conv2d(&xin));
                    });
                    let speedup = s_scalar.median / s_vec.median;
                    if speedup > best.0 {
                        best = (speedup, format!("{} {backend}", info.name));
                    }
                    record(
                        &mut records,
                        &format!("simd_{backend}_{}", info.name),
                        s_vec,
                        format!("width {widest}, {speedup:.2}x vs forced scalar"),
                    );
                    extras.push((format!("simd_{backend}_speedup_{}", info.name), speedup));
                    rows.push(vec![
                        format!("simd {} {backend} ({widest})", info.name),
                        format!(
                            "{:.3} ms vs {:.3} ms scalar",
                            s_vec.median * 1e3,
                            s_scalar.median * 1e3
                        ),
                        format!("{speedup:.2}x"),
                    ]);
                    // Noise guard: the vector path must never lose to
                    // scalar beyond shared-runner jitter.
                    assert!(
                        speedup >= 0.90,
                        "{} {backend}: vector path {:.3} ms regressed vs scalar {:.3} ms",
                        info.name,
                        s_vec.median * 1e3,
                        s_scalar.median * 1e3
                    );
                }
            }
            extras.push(("simd_best_layer_speedup".into(), best.0));
            rows.push(vec![
                "simd best layer speedup".into(),
                format!("{:.2}x", best.0),
                best.1.clone(),
            ]);
            if widest == VectorWidth::W8 {
                assert!(
                    best.0 >= 1.5,
                    "8-lane kernels must clear 1.5x on some vgg_tiny layer \
                     (best {:.2}x on {})",
                    best.0,
                    best.1
                );
            } else {
                println!(
                    "simd: widest width is {widest}; the 1.5x headline gate needs an \
                     8-lane ISA and is skipped"
                );
            }
        }
    }

    // ------------------------------------------------------------------
    // Simulator hot paths.
    // ------------------------------------------------------------------
    let a = rng.gaussian_vec(64 * 64);
    let b = rng.gaussian_vec(64 * 64);
    let s = time_it(2, 10, || {
        let mut cl = Cluster::new(4);
        std::hint::black_box(cl.matmul(
            &BlockMatrix::new(&a, 64, 64, 4),
            &BlockMatrix::new(&b, 64, 64, 4),
        ));
    });
    record(&mut records, "cluster_dense_64", s, "fast functional path".into());
    let macs = BlockTiming::new(4).dense_macs(64, 64, 64) as f64;
    rows.push(vec![
        "cluster sim 64^3 dense".into(),
        format!("{:.3} ms", s.mean * 1e3),
        format!("{} MAC/s simulated", eng(macs / s.mean)),
    ]);

    // Sparse cluster simulation at 90%.
    let bs = synthetic_sparse_matrix(&mut rng, 64, 64, 4, 0.9);
    let bcoo = Bcoo::compress(&bs, 64, 64, 4);
    let s = time_it(2, 10, || {
        let mut cl = Cluster::new(4);
        std::hint::black_box(cl.matmul_sparse(&BlockMatrix::new(&a, 64, 64, 4), &bcoo));
    });
    record(&mut records, "cluster_sparse90_64", s, String::new());
    rows.push(vec![
        "cluster sim 64^3 sparse90".into(),
        format!("{:.3} ms", s.mean * 1e3),
        String::new(),
    ]);

    // Closed-form timing model (the sweep hot path).
    let t = BlockTiming::new(4);
    let s = time_it(10, 50, || {
        std::hint::black_box(t.sparse_matmul_cycles(512, &bcoo));
    });
    record(&mut records, "timing_model_sparse_walk", s, String::new());
    rows.push(vec![
        "timing model sparse walk".into(),
        format!("{:.1} µs", s.mean * 1e6),
        String::new(),
    ]);

    // Z-Morton encode/decode throughput.
    let s = time_it(2, 20, || {
        let mut acc = 0u64;
        for i in 0..1_000_000u32 {
            acc = acc.wrapping_add(swcnn::zmorton::encode(i, i ^ 0xAAAA));
        }
        std::hint::black_box(acc);
    });
    record(&mut records, "zmorton_encode_1e6", s, String::new());
    rows.push(vec![
        "zmorton encode x1e6".into(),
        format!("{:.2} ms", s.mean * 1e3),
        format!("{} enc/s", eng(1e6 / s.mean)),
    ]);

    // BCOO compression of a VGG-scale weight matrix.
    let big = synthetic_sparse_matrix(&mut rng, 512, 512, 4, 0.8);
    let s = time_it(2, 10, || {
        std::hint::black_box(Bcoo::compress(&big, 512, 512, 4));
    });
    record(&mut records, "bcoo_compress_512", s, String::new());
    rows.push(vec![
        "BCOO compress 512x512".into(),
        format!("{:.2} ms", s.mean * 1e3),
        String::new(),
    ]);

    // PJRT execution latency (needs the `pjrt` feature AND artifacts;
    // without the feature the stub runtime refuses to compile artifacts,
    // so entering this block would panic and lose the whole report).
    if cfg!(feature = "pjrt") && std::path::Path::new("artifacts/manifest.json").exists() {
        use swcnn::runtime::Runtime;
        let mut rt = Runtime::new("artifacts").expect("runtime");
        for name in ["quickstart", "vgg_tiny_b1", "vgg_tiny_b4", "vgg16_conv5"] {
            let model = rt.load(name).expect(name);
            let n_in: usize = model
                .spec
                .request_inputs()
                .next()
                .map(|i| i.elements())
                .unwrap_or(0);
            let xin = Rng::new(7).gaussian_vec(n_in);
            let s = time_it(3, 20, || {
                std::hint::black_box(model.run(&[xin.clone()]).expect("run"));
            });
            record(&mut records, &format!("pjrt_{name}"), s, String::new());
            let per_img = match name {
                "vgg_tiny_b4" => s.mean / 4.0,
                _ => s.mean,
            };
            rows.push(vec![
                format!("pjrt {name}"),
                format!("{:.3} ms", s.mean * 1e3),
                format!("{:.3} ms/img", per_img * 1e3),
            ]);
        }
    } else {
        rows.push(vec![
            "pjrt artifacts".into(),
            "skipped".into(),
            "needs --features pjrt and `make artifacts`".into(),
        ]);
    }

    print_table("hot paths", &["path", "time", "notes"], &rows);
    write_json(&records, &extras);
}
